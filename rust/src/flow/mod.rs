//! The unified flow API — one typed builder for the paper's Fig 1
//! pipeline: **map** an application onto named processing elements,
//! **wrap** them (Data Collector / Processor / Data Distributor) and plug
//! them onto a CONNECT-style NoC, optionally **partition** the NoC across
//! FPGAs over quasi-SERDES links, and **run** the whole system to
//! quiescence with a unified [`RunReport`].
//!
//! Before this module, every case study hand-wired
//! `Network::new → PeSystem::new → Partition::apply` with copy-pasted
//! boilerplate and ad-hoc result types. [`FlowBuilder`] is now the single
//! construction path (the three case studies and the examples all build
//! through it); [`crate::pe::PeSystem`] and [`crate::noc::Network`]
//! remain public as the low-level layer.
//!
//! A flow is assembled from:
//!
//! * **PEs** — named [`Processor`]s, pinned to an endpoint
//!   ([`FlowBuilder::pe_at`], the paper's manual mode) or auto-placed
//!   ([`FlowBuilder::pe`]) by the bisection-driven placer in [`placer`].
//! * **Taps** — named bare endpoints whose eject queues the host reads
//!   ([`MappedFlow::drain`] / [`MappedFlow::drain_messages`]) — the
//!   paper's sink nodes.
//! * **Channels** — logical `src → dst` message edges. They carry no
//!   simulation semantics (routing is the NoC's job) but drive
//!   auto-placement locality and document the application graph.
//! * **Topology** — explicit, or an auto-sized mesh.
//! * **Partition** — a user cut ([`FlowBuilder::partition`], the paper's
//!   mode), or [`FlowBuilder::auto_partition`] via
//!   [`Partition::balanced`]'s min-cut bisection; either installs
//!   quasi-SERDES endpoints on every cut link.
//!
//! [`FlowBuilder::build`] validates the configuration
//! ([`NocConfig::validate`]), the layout (names, endpoints, partition
//! shape) and returns a [`MappedFlow`]; [`MappedFlow::run`] steps the
//! system to quiescence and reports cycles, [`NetStats`], per-PE
//! invocation/busy statistics, per-FPGA resource estimates and serdes
//! overhead in one [`RunReport`]. [`MappedFlow::run_batch`] drives a
//! fresh flow per input for batched experiments.

pub mod placer;

use std::collections::BTreeMap;
use std::fmt;

use crate::noc::flit::{depacketize, Flit, NodeId};
use crate::noc::multichip::{LinkStat, MultiChipError, MultiChipSim};
use crate::noc::{ChannelProfile, NetStats, Network, NocConfig, SimEngine, Topology};
use crate::partition::Partition;
use crate::pe::collector::split_tag;
use crate::pe::{MultiChipPeSystem, PeSystem, Processor, WrappedPe};
use crate::resources::{Device, Resources};
use crate::serdes::{wire_bits, FaultPlan, SerdesConfig};

/// Errors surfaced by [`FlowBuilder::build`] and [`MappedFlow::run`]
/// (instead of the low-level layer's panics).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FlowError {
    /// Invalid NoC configuration (see [`NocConfig::validate`]).
    Config(String),
    /// Invalid flow layout: duplicate names, endpoint collisions,
    /// topology too small, malformed partition, …
    Layout(String),
    /// The system did not reach quiescence within the cycle budget
    /// (protocol deadlock / livelock guard).
    Timeout { cycles: u64, pending: usize },
    /// An **unprotected** wire link delivered an unreconstructable frame
    /// under fault injection ([`FlowBuilder::fault_plan`] with
    /// [`FaultPlan::unprotected`]): the header was corrupted and there is
    /// no CRC to trigger a retransmission.
    Link { link: usize, cycle: u64 },
}

impl fmt::Display for FlowError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FlowError::Config(msg) => write!(f, "invalid NoC config: {msg}"),
            FlowError::Layout(msg) => write!(f, "invalid flow layout: {msg}"),
            FlowError::Timeout { cycles, pending } => write!(
                f,
                "flow not quiescent after {cycles} cycles ({pending} flits pending)"
            ),
            FlowError::Link { link, cycle } => write!(
                f,
                "unreconstructable frame on unprotected wire link {link} at cycle \
                 {cycle} (enable CRC protection to retransmit instead)"
            ),
        }
    }
}

impl std::error::Error for FlowError {}

/// Per-PE statistics in a [`RunReport`].
#[derive(Clone, Debug)]
pub struct PeRunStat {
    pub name: String,
    pub node: NodeId,
    /// FPGA hosting the PE's router (0 when unpartitioned).
    pub fpga: usize,
    /// Invocations completed (paper: `start`…`done` handshakes).
    pub invocations: u64,
    /// Cycles the datapath was busy.
    pub busy_cycles: u64,
}

/// The unified result of one flow run: every quantity the case studies
/// used to compute by hand from `Network`/`PeSystem` internals.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Flow name (from [`FlowBuilder::new`]).
    pub flow: String,
    /// Cycles from the start of this run to quiescence.
    pub cycles: u64,
    /// Network counters (injected/delivered flits, latency, throughput).
    pub net: NetStats,
    /// Per-PE invocation/busy statistics.
    pub pes: Vec<PeRunStat>,
    /// FPGAs the NoC is partitioned across (1 = monolithic).
    pub n_fpgas: usize,
    /// NoC links cut by the partition.
    pub cut_links: usize,
    /// Quasi-SERDES serialization latency per flit (0 when unpartitioned).
    pub serdes_cycles_per_flit: u64,
    /// Flits carried over all quasi-SERDES channels.
    pub serdes_flits: u64,
    /// FPGA pins dedicated to quasi-SERDES links, per FPGA.
    pub pins_per_fpga: Vec<usize>,
    /// Resource estimate per FPGA: routers + serdes endpoints + PE
    /// wrappers (+ any [`FlowBuilder::pe_resources`] app datapaths).
    pub resources_per_fpga: Vec<Resources>,
    /// Per-chip [`NetStats`] of a sharded run ([`FlowBuilder::multichip`]
    /// / [`RunReport::from_multichip`]); empty for monolithic runs.
    pub per_chip: Vec<NetStats>,
    /// Per-wire-link occupancy/stall statistics of a sharded run; empty
    /// for monolithic runs.
    pub links: Vec<LinkStat>,
}

impl RunReport {
    /// Report for a bare-network run (no PEs attached) — the reporting
    /// path of the scenario matrix ([`crate::noc::scenario`]), so
    /// network-only experiments speak the same result vocabulary as full
    /// flows.
    pub fn from_network(name: &str, cycles: u64, net: &Network) -> RunReport {
        let serdes_flits = net.serdes_channels().map(|(_, c)| c.carried).sum();
        let serdes_cycles_per_flit =
            net.serdes_channels().next().map_or(0, |(_, c)| c.ser_cycles);
        RunReport {
            flow: name.to_string(),
            cycles,
            net: net.stats().clone(),
            pes: Vec::new(),
            n_fpgas: 1,
            cut_links: net.serdes_channels().count(),
            serdes_cycles_per_flit,
            serdes_flits,
            pins_per_fpga: vec![0],
            resources_per_fpga: vec![net.topo().router_resources(net.cfg())],
            per_chip: Vec::new(),
            links: Vec::new(),
        }
    }

    /// Report for a bare sharded-fabric run (no PEs attached) — the
    /// multi-chip reporting path of the scenario matrix, with per-chip
    /// `NetStats` and per-link occupancy alongside the combined counters.
    pub fn from_multichip(name: &str, cycles: u64, sim: &MultiChipSim) -> RunReport {
        let partition = sim.partition();
        let topo = sim.global_topo();
        let serdes = sim.serdes_cfg();
        RunReport {
            flow: name.to_string(),
            cycles,
            net: sim.stats(),
            pes: Vec::new(),
            n_fpgas: sim.n_chips(),
            cut_links: sim.n_cut_links(),
            serdes_cycles_per_flit: sim.serdes_cycles_per_flit(),
            serdes_flits: sim.wire_flits(),
            pins_per_fpga: partition.pins_per_fpga(topo, serdes),
            resources_per_fpga: partition.noc_resources_per_fpga(topo, sim.cfg(), serdes),
            per_chip: sim.chips().iter().map(|c| c.stats().clone()).collect(),
            links: sim.link_stats(),
        }
    }

    /// Total PE invocations.
    pub fn total_invocations(&self) -> u64 {
        self.pes.iter().map(|p| p.invocations).sum()
    }

    /// Total PE busy cycles.
    pub fn total_busy_cycles(&self) -> u64 {
        self.pes.iter().map(|p| p.busy_cycles).sum()
    }

    /// Does every FPGA's estimate fit `device`?
    pub fn fits(&self, device: &Device) -> bool {
        self.resources_per_fpga.iter().all(|&r| device.fits(r))
    }
}

impl fmt::Display for RunReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "flow '{}': {} cycles, {} PEs / {} invocations on {} FPGA(s)",
            self.flow,
            self.cycles,
            self.pes.len(),
            self.total_invocations(),
            self.n_fpgas
        )?;
        if self.cut_links > 0 {
            write!(
                f,
                ", {} links cut ({} serdes flits @ {} cycles/flit)",
                self.cut_links, self.serdes_flits, self.serdes_cycles_per_flit
            )?;
        }
        write!(f, " | {}", self.net)
    }
}

/// A reassembled message drained from a tap endpoint.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TapMessage {
    /// Sending endpoint.
    pub src: NodeId,
    /// Message epoch (invocation / frame / iteration index).
    pub epoch: u32,
    /// Destination argument index.
    pub arg: u8,
    /// Payload words (little-endian bit order, as
    /// [`crate::noc::flit::depacketize`] produces).
    pub words: Vec<u64>,
}

struct PeSlot {
    name: String,
    node: Option<NodeId>,
    proc_: Option<Box<dyn Processor>>,
}

struct TapSlot {
    name: String,
    node: Option<NodeId>,
}

enum PartitionSpec {
    Whole,
    Manual(Partition),
    Auto(usize),
}

/// Builder for the full map → wrap → partition → run pipeline. See the
/// [module docs](self) for the vocabulary and `examples/quickstart.rs`
/// for an end-to-end walkthrough.
pub struct FlowBuilder {
    name: String,
    cfg: NocConfig,
    topo: Option<Topology>,
    serdes: SerdesConfig,
    partition: PartitionSpec,
    multichip: bool,
    fault: Option<FaultPlan>,
    pinned: Vec<(String, String)>,
    pes: Vec<PeSlot>,
    taps: Vec<TapSlot>,
    channels: Vec<(String, String, u64)>,
    measured: Option<ChannelProfile>,
    extra_resources: Vec<(String, Resources)>,
    max_cycles: u64,
    seed: u64,
}

impl FlowBuilder {
    /// Start a flow with the paper's NoC configuration, no partition, and
    /// an auto-sized mesh unless [`FlowBuilder::topology`] is called.
    pub fn new(name: &str) -> Self {
        FlowBuilder {
            name: name.to_string(),
            cfg: NocConfig::paper(),
            topo: None,
            serdes: SerdesConfig::default(),
            partition: PartitionSpec::Whole,
            multichip: false,
            fault: None,
            pinned: Vec::new(),
            pes: Vec::new(),
            taps: Vec::new(),
            channels: Vec::new(),
            measured: None,
            extra_resources: Vec::new(),
            max_cycles: 2_000_000_000,
            seed: 0,
        }
    }

    /// Override the NoC configuration (validated at [`FlowBuilder::build`]).
    pub fn noc(&mut self, cfg: NocConfig) -> &mut Self {
        self.cfg = cfg;
        self
    }

    /// Select the simulation engine: the cycle-stepped
    /// [`SimEngine::Reference`] or the event-driven
    /// [`SimEngine::EventDriven`] fast path, which skips idle routers and
    /// produces bit-identical results (cycles, stats, eject order):
    ///
    /// ```
    /// use fabricflow::flow::FlowBuilder;
    /// use fabricflow::noc::{SimEngine, Topology};
    /// use fabricflow::pe::collector::ArgMessage;
    /// use fabricflow::pe::{MsgSink, Processor, WrapperSpec};
    ///
    /// /// Boot-time source: one 16-bit message to the tap at endpoint 1.
    /// struct Ping;
    /// impl Processor for Ping {
    ///     fn spec(&self) -> WrapperSpec { WrapperSpec::new(vec![16], vec![16]) }
    ///     fn boot(&mut self, out: &mut MsgSink) {
    ///         out.word(1, 0, 0, 99, 16);
    ///     }
    ///     fn process(&mut self, _: &[ArgMessage], _: u32, _: &mut MsgSink) {}
    /// }
    ///
    /// let run = |engine: SimEngine| {
    ///     let mut fb = FlowBuilder::new("engine-demo");
    ///     fb.topology(Topology::Mesh { w: 2, h: 2 })
    ///         .engine(engine)
    ///         .pe_at("src", 0, Box::new(Ping))
    ///         .tap_at("sink", 1);
    ///     let mut flow = fb.build().unwrap();
    ///     let report = flow.run().unwrap();
    ///     (report.cycles, flow.drain("sink").len())
    /// };
    /// assert_eq!(run(SimEngine::Reference), run(SimEngine::EventDriven));
    /// ```
    pub fn engine(&mut self, engine: SimEngine) -> &mut Self {
        self.cfg.engine = engine;
        self
    }

    /// Pick the topology explicitly. Without this, `build` sizes a mesh
    /// to fit every PE and tap.
    pub fn topology(&mut self, topo: Topology) -> &mut Self {
        self.topo = Some(topo);
        self
    }

    /// Quasi-SERDES link parameters used on cut links.
    pub fn serdes(&mut self, serdes: SerdesConfig) -> &mut Self {
        self.serdes = serdes;
        self
    }

    /// Partition the NoC with a user-specified cut (the paper's mode).
    pub fn partition(&mut self, partition: Partition) -> &mut Self {
        self.partition = PartitionSpec::Manual(partition);
        self
    }

    /// Partition automatically into `n_fpgas` parts via
    /// [`Partition::balanced`] (seeded by [`FlowBuilder::seed`]).
    pub fn auto_partition(&mut self, n_fpgas: usize) -> &mut Self {
        self.partition = PartitionSpec::Auto(n_fpgas);
        self
    }

    /// Run the partitioned flow as a true sharded co-simulation: one
    /// [`Network`] per FPGA, cut links bridged by cycle-true serializing
    /// wire channels ([`MultiChipSim`]). Results are identical to the
    /// monolithic simulation (same messages, same per-source order at
    /// each destination) with honest cross-chip link timing, and
    /// [`RunReport`] gains per-chip [`NetStats`] plus per-link
    /// occupancy/stall statistics. Requires a partition
    /// ([`FlowBuilder::partition`] / [`FlowBuilder::auto_partition`]).
    ///
    /// Cut-crossing flits are genuinely serialized, and the wire format
    /// carries a 16-bit tag (`(epoch << 8) | arg`) and an 8-bit flit
    /// sequence number — sharded flows therefore need message epochs
    /// < 256 and messages of ≤ 256 flits; the wire channel asserts
    /// loudly otherwise instead of corrupting silently.
    pub fn multichip(&mut self, serdes: SerdesConfig) -> &mut Self {
        self.serdes = serdes;
        self.multichip = true;
        self
    }

    /// Inject seeded faults on the sharded co-simulation's wire channels
    /// (bit flips, flit drops, link/chip outage windows — see
    /// [`FaultPlan`]). Protected plans (the default) add a CRC to the
    /// wire format and recover every fault by retransmission, so the
    /// flow's results are unchanged and only its timing degrades; an
    /// [`FaultPlan::unprotected`] plan lets header corruption surface as
    /// [`FlowError::Link`]. Requires [`FlowBuilder::multichip`] — the
    /// monolithic backend has no inter-FPGA wires to be faulty.
    pub fn fault_plan(&mut self, plan: FaultPlan) -> &mut Self {
        self.fault = Some(plan);
        self
    }

    /// Keep two endpoint-pinned units' routers on the same FPGA (e.g. a
    /// PE and the tap collecting its results — the pfilter root and its
    /// histogram sink). Under [`FlowBuilder::auto_partition`] the pair
    /// constrains the bisection ([`Partition::balanced_pinned`]); with a
    /// manual [`FlowBuilder::partition`] the pair is validated against
    /// the given cut. Both units must be placed with
    /// [`FlowBuilder::pe_at`] / [`FlowBuilder::tap_at`]; an
    /// unsatisfiable or violated constraint surfaces as a typed
    /// [`FlowError::Layout`] instead of a partitioner panic or a silent
    /// no-op.
    pub fn pin_together(&mut self, a: &str, b: &str) -> &mut Self {
        self.pinned.push((a.to_string(), b.to_string()));
        self
    }

    /// Seed for the automatic partitioner.
    pub fn seed(&mut self, seed: u64) -> &mut Self {
        self.seed = seed;
        self
    }

    /// Cycle budget for [`MappedFlow::run`] (deadlock guard).
    pub fn max_cycles(&mut self, max_cycles: u64) -> &mut Self {
        self.max_cycles = max_cycles;
        self
    }

    /// Register a PE for automatic placement.
    pub fn pe(&mut self, name: &str, processor: Box<dyn Processor>) -> &mut Self {
        self.pes.push(PeSlot { name: name.to_string(), node: None, proc_: Some(processor) });
        self
    }

    /// Register a PE pinned to endpoint `node` (the paper's manual maps:
    /// Fig 9's bit/check grid, Fig 10's root on Node 0, …).
    pub fn pe_at(
        &mut self,
        name: &str,
        node: NodeId,
        processor: Box<dyn Processor>,
    ) -> &mut Self {
        self.pes.push(PeSlot {
            name: name.to_string(),
            node: Some(node),
            proc_: Some(processor),
        });
        self
    }

    /// Declare the application-datapath resources of PE `name` (added to
    /// its wrapper overhead in [`RunReport::resources_per_fpga`]).
    pub fn pe_resources(&mut self, name: &str, resources: Resources) -> &mut Self {
        self.extra_resources.push((name.to_string(), resources));
        self
    }

    /// Register a tap (bare host-read endpoint) for automatic placement.
    pub fn tap(&mut self, name: &str) -> &mut Self {
        self.taps.push(TapSlot { name: name.to_string(), node: None });
        self
    }

    /// Register a tap pinned to endpoint `node`.
    pub fn tap_at(&mut self, name: &str, node: NodeId) -> &mut Self {
        self.taps.push(TapSlot { name: name.to_string(), node: Some(node) });
        self
    }

    /// Declare a logical channel between two named PEs/taps (weight 1).
    pub fn channel(&mut self, from: &str, to: &str) -> &mut Self {
        self.channel_weighted(from, to, 1)
    }

    /// Declare a weighted logical channel (heavier channels bind tighter
    /// under auto-placement).
    pub fn channel_weighted(&mut self, from: &str, to: &str, weight: u64) -> &mut Self {
        self.channels.push((from.to_string(), to.to_string(), weight));
        self
    }

    /// Close the measure → re-place loop: drive the bisection-aware
    /// placer with **measured** channel loads instead of the declared
    /// weights. `profile` is the flit-hop profile of a previous run of
    /// the *same* flow, keyed by unit index (PEs in registration order,
    /// then taps) — exactly what [`MappedFlow::unit_channel_profile`]
    /// returns after a traced run ([`MappedFlow::enable_trace`]).
    ///
    /// At [`FlowBuilder::build`], every declared channel whose unit pair
    /// carried measured traffic has its weight replaced by the measured
    /// flit-hops, and measured pairs with no declared channel are added
    /// as new placement edges — so a hotspot the application graph
    /// under-declared still binds tight. Declared channels with no
    /// measured traffic keep their declared weight.
    pub fn profile_guided(&mut self, profile: ChannelProfile) -> &mut Self {
        self.measured = Some(profile);
        self
    }

    fn unit_index(&self, name: &str) -> Option<usize> {
        self.pes
            .iter()
            .position(|p| p.name == name)
            .or_else(|| {
                self.taps
                    .iter()
                    .position(|t| t.name == name)
                    .map(|i| i + self.pes.len())
            })
    }

    /// Validate, place, wrap and wire the flow into a runnable
    /// [`MappedFlow`]. Consumes the registered processors: a second
    /// `build` on the same builder is an error.
    pub fn build(&mut self) -> Result<MappedFlow, FlowError> {
        self.cfg.validate().map_err(FlowError::Config)?;
        if self.pes.is_empty() {
            return Err(FlowError::Layout("flow has no processing elements".into()));
        }
        // Unique names across PEs and taps.
        let mut names: Vec<&str> = self
            .pes
            .iter()
            .map(|p| p.name.as_str())
            .chain(self.taps.iter().map(|t| t.name.as_str()))
            .collect();
        names.sort_unstable();
        for w in names.windows(2) {
            if w[0] == w[1] {
                return Err(FlowError::Layout(format!("duplicate name '{}'", w[0])));
            }
        }
        for (name, _) in &self.extra_resources {
            if !self.pes.iter().any(|p| p.name == *name) {
                return Err(FlowError::Layout(format!(
                    "pe_resources for unknown PE '{name}'"
                )));
            }
        }
        let n_units = self.pes.len() + self.taps.len();
        let topo = match &self.topo {
            Some(t) => t.clone(),
            None => {
                let w = ((n_units as f64).sqrt().ceil() as usize).max(2);
                let h = n_units.div_ceil(w).max(1);
                Topology::Mesh { w, h }
            }
        };
        let graph = topo.build();
        let n_eps = graph.n_endpoints;
        if n_units > n_eps {
            return Err(FlowError::Layout(format!(
                "{n_units} PEs/taps but topology {topo:?} has only {n_eps} endpoints"
            )));
        }
        // Pinned endpoints: in range, collision-free.
        let fixed: Vec<Option<NodeId>> = self
            .pes
            .iter()
            .map(|p| p.node)
            .chain(self.taps.iter().map(|t| t.node))
            .collect();
        let mut used = vec![false; n_eps];
        for (u, &node) in fixed.iter().enumerate() {
            let Some(node) = node else { continue };
            if node >= n_eps {
                return Err(FlowError::Layout(format!(
                    "'{}' pinned to endpoint {node} but topology has {n_eps}",
                    names_at(&self.pes, &self.taps, u)
                )));
            }
            if used[node] {
                return Err(FlowError::Layout(format!(
                    "endpoint {node} assigned twice (second: '{}')",
                    names_at(&self.pes, &self.taps, u)
                )));
            }
            used[node] = true;
        }
        // Resolve pin_together pairs to their routers: both units must
        // be endpoint-pinned so the routers are known before placement.
        // The pairs are honored in EVERY partition mode below (auto
        // constrains the bisection, manual is validated, whole is
        // trivially co-located) — never silently dropped.
        let mut pinned_pairs = Vec::with_capacity(self.pinned.len());
        for (a, b) in &self.pinned {
            let router_of = |name: &str| -> Result<usize, FlowError> {
                let u = self.unit_index(name).ok_or_else(|| {
                    FlowError::Layout(format!(
                        "pin_together endpoint '{name}' is not a PE or tap"
                    ))
                })?;
                let ep = fixed[u].ok_or_else(|| {
                    FlowError::Layout(format!(
                        "pin_together('{name}') needs an endpoint-pinned \
                         unit (use pe_at/tap_at)"
                    ))
                })?;
                Ok(graph.endpoint_router(ep))
            };
            pinned_pairs.push((a.as_str(), b.as_str(), router_of(a)?, router_of(b)?));
        }
        // Resolve the partition before placement so the placer can see it.
        let partition = match &self.partition {
            // One FPGA: every pinned pair trivially shares it.
            PartitionSpec::Whole => None,
            PartitionSpec::Manual(p) => {
                if p.assignment.len() != graph.n_routers {
                    return Err(FlowError::Layout(format!(
                        "partition covers {} routers but topology has {}",
                        p.assignment.len(),
                        graph.n_routers
                    )));
                }
                for &(a, b, ra, rb) in &pinned_pairs {
                    if p.assignment[ra] != p.assignment[rb] {
                        return Err(FlowError::Layout(format!(
                            "partition splits pinned pair '{a}'/'{b}' \
                             (routers {ra} and {rb} on different FPGAs)"
                        )));
                    }
                }
                Some(p.clone())
            }
            PartitionSpec::Auto(k) => {
                if *k < 1 || *k > graph.n_routers {
                    return Err(FlowError::Layout(format!(
                        "cannot split {} routers across {k} FPGAs",
                        graph.n_routers
                    )));
                }
                if pinned_pairs.is_empty() {
                    Some(Partition::balanced(&graph, *k, self.seed))
                } else {
                    let pairs: Vec<(usize, usize)> =
                        pinned_pairs.iter().map(|&(_, _, ra, rb)| (ra, rb)).collect();
                    let p = Partition::balanced_pinned(&graph, *k, self.seed, &pairs)
                        .map_err(|e| {
                            FlowError::Layout(format!("auto-partition: {e}"))
                        })?;
                    Some(p)
                }
            }
        };
        if self.multichip && partition.is_none() {
            return Err(FlowError::Layout(
                "multichip() needs a partition (partition()/auto_partition())".into(),
            ));
        }
        if self.fault.is_some() && !self.multichip {
            return Err(FlowError::Layout(
                "fault_plan() needs the sharded co-simulation (multichip())".into(),
            ));
        }
        // Resolve channels to unit indices.
        let mut edges = Vec::with_capacity(self.channels.len());
        for (a, b, w) in &self.channels {
            let ia = self.unit_index(a).ok_or_else(|| {
                FlowError::Layout(format!("channel endpoint '{a}' is not a PE or tap"))
            })?;
            let ib = self.unit_index(b).ok_or_else(|| {
                FlowError::Layout(format!("channel endpoint '{b}' is not a PE or tap"))
            })?;
            edges.push((ia, ib, *w));
        }
        // Profile-guided mode: measured flit-hops displace the declared
        // weights (the placer treats channel direction as symmetric, so
        // a pair's two directions sum).
        if let Some(measured) = &self.measured {
            let mut loads: BTreeMap<(usize, usize), u64> = BTreeMap::new();
            for ((s, d), n) in measured.iter() {
                let (s, d) = (s as usize, d as usize);
                if s < n_units && d < n_units && s != d {
                    *loads.entry((s.min(d), s.max(d))).or_insert(0) += n;
                }
            }
            let mut covered: Vec<(usize, usize)> = Vec::with_capacity(edges.len());
            for (ia, ib, w) in &mut edges {
                let key = ((*ia).min(*ib), (*ia).max(*ib));
                if let Some(&n) = loads.get(&key) {
                    *w = n;
                }
                covered.push(key);
            }
            for (&(a, b), &n) in &loads {
                if n > 0 && !covered.contains(&(a, b)) {
                    edges.push((a, b, n));
                }
            }
        }
        // Place the unpinned units (bisection-aware when partitioned).
        let cut_penalty = if partition.is_some() {
            self.serdes
                .cycles_per_flit(wire_bits(self.cfg.flit_data_width, n_eps))
        } else {
            0
        };
        let place = placer::auto_place(&graph, &fixed, &edges, partition.as_ref(), cut_penalty)
            .map_err(FlowError::Layout)?;
        // Wire the system: a monolithic network (serdes spliced into cut
        // links) or the sharded multi-chip fabric of one Network per FPGA.
        let cut_links = partition.as_ref().map_or(0, |p| p.cut_links(&graph).len());
        let mut sim = if self.multichip {
            let p = partition.as_ref().expect("checked above");
            let mut mcs = MultiChipSim::from_graph(graph, self.cfg, p, self.serdes);
            if let Some(plan) = &self.fault {
                mcs.set_fault_plan(plan);
            }
            FlowSim::Sharded(MultiChipPeSystem::new(mcs))
        } else {
            let mut net = Network::new(&topo, self.cfg);
            if let Some(p) = &partition {
                p.apply(&mut net, self.serdes);
            }
            FlowSim::Mono(PeSystem::new(net))
        };
        let n_pes = self.pes.len();
        let mut pe_names = Vec::with_capacity(n_pes);
        let mut pe_resources = Vec::with_capacity(n_pes);
        for (i, slot) in self.pes.iter_mut().enumerate() {
            let proc_ = slot.proc_.take().ok_or_else(|| {
                FlowError::Layout(format!(
                    "PE '{}' already consumed by an earlier build()",
                    slot.name
                ))
            })?;
            let mut r = proc_.spec().resources();
            if let Some((_, extra)) =
                self.extra_resources.iter().find(|(n, _)| *n == slot.name)
            {
                r += *extra;
            }
            match &mut sim {
                FlowSim::Mono(sys) => sys.attach(place[i], proc_),
                FlowSim::Sharded(sys) => sys.attach(place[i], proc_),
            }
            pe_names.push((slot.name.clone(), place[i]));
            pe_resources.push(r);
        }
        let tap_names: Vec<(String, NodeId)> = self
            .taps
            .iter()
            .enumerate()
            .map(|(i, t)| (t.name.clone(), place[n_pes + i]))
            .collect();
        Ok(MappedFlow {
            name: self.name.clone(),
            sim,
            pe_names,
            tap_names,
            pe_resources,
            partition,
            serdes: self.serdes,
            cut_links,
            max_cycles: self.max_cycles,
        })
    }
}

fn names_at(pes: &[PeSlot], taps: &[TapSlot], unit: usize) -> String {
    if unit < pes.len() {
        pes[unit].name.clone()
    } else {
        taps[unit - pes.len()].name.clone()
    }
}

/// The simulation backend of a [`MappedFlow`]: one monolithic network
/// (serdes channels spliced into cut links) or the sharded multi-chip
/// fabric ([`FlowBuilder::multichip`]).
enum FlowSim {
    Mono(PeSystem),
    Sharded(MultiChipPeSystem),
}

impl FlowSim {
    fn step(&mut self) {
        match self {
            FlowSim::Mono(sys) => sys.step(),
            FlowSim::Sharded(sys) => sys.step(),
        }
    }

    fn quiescent(&self) -> bool {
        match self {
            FlowSim::Mono(sys) => sys.quiescent(),
            FlowSim::Sharded(sys) => sys.quiescent(),
        }
    }

    fn cycle(&self) -> u64 {
        match self {
            FlowSim::Mono(sys) => sys.net.cycle(),
            FlowSim::Sharded(sys) => sys.sim.cycle(),
        }
    }

    fn pending(&self) -> usize {
        match self {
            FlowSim::Mono(sys) => sys.net.pending(),
            FlowSim::Sharded(sys) => sys.sim.pending(),
        }
    }

    /// Latched wire-link fault of a sharded backend (monolithic networks
    /// have no lossy wires and always report `None`).
    fn wire_error(&self) -> Option<MultiChipError> {
        match self {
            FlowSim::Mono(_) => None,
            FlowSim::Sharded(sys) => sys.sim.wire_error(),
        }
    }

    fn eject(&mut self, node: NodeId) -> Option<Flit> {
        match self {
            FlowSim::Mono(sys) => sys.net.eject(node),
            FlowSim::Sharded(sys) => sys.sim.eject(node),
        }
    }

    fn flit_width(&self) -> u32 {
        match self {
            FlowSim::Mono(sys) => sys.net.cfg().flit_data_width,
            FlowSim::Sharded(sys) => sys.sim.cfg().flit_data_width,
        }
    }

    fn pe(&self, node: NodeId) -> Option<&WrappedPe> {
        match self {
            FlowSim::Mono(sys) => sys.pe(node),
            FlowSim::Sharded(sys) => sys.pe(node),
        }
    }

    fn readback(&self, node: NodeId) -> Option<Vec<u64>> {
        match self {
            FlowSim::Mono(sys) => sys.readback(node),
            FlowSim::Sharded(sys) => sys.readback(node),
        }
    }

    fn endpoint_router(&self, node: NodeId) -> usize {
        match self {
            FlowSim::Mono(sys) => sys.net.topo().endpoint_router(node),
            FlowSim::Sharded(sys) => sys.sim.global_topo().endpoint_router(node),
        }
    }
}

/// A built flow: wrapped PEs plugged onto the (possibly partitioned) NoC,
/// ready to run. The phase-1 + phase-2 result of the paper's pipeline.
pub struct MappedFlow {
    name: String,
    sim: FlowSim,
    pe_names: Vec<(String, NodeId)>,
    tap_names: Vec<(String, NodeId)>,
    pe_resources: Vec<Resources>,
    partition: Option<Partition>,
    serdes: SerdesConfig,
    cut_links: usize,
    max_cycles: u64,
}

impl MappedFlow {
    /// Flow name.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Endpoint a named PE or tap landed on (manual or auto-placed).
    pub fn node_of(&self, name: &str) -> Option<NodeId> {
        self.pe_names
            .iter()
            .chain(self.tap_names.iter())
            .find(|(n, _)| n.as_str() == name)
            .map(|&(_, node)| node)
    }

    /// The resolved partition (None when monolithic).
    pub fn partition(&self) -> Option<&Partition> {
        self.partition.as_ref()
    }

    /// Turn on flit-event tracing in the underlying simulator (both
    /// backends) with a ring buffer of `capacity` events per network.
    /// The run itself is bit-identical either way; the trace only
    /// observes. See [`crate::noc::TraceBuffer`].
    pub fn enable_trace(&mut self, capacity: usize) {
        match &mut self.sim {
            FlowSim::Mono(sys) => sys.net.enable_trace(capacity),
            FlowSim::Sharded(sys) => sys.sim.enable_trace(capacity),
        }
    }

    /// Measured flit-hops per `(src, dst)` **endpoint** pair of a traced
    /// run (exact regardless of ring capacity; empty when tracing is
    /// off).
    pub fn channel_profile(&self) -> ChannelProfile {
        match &self.sim {
            FlowSim::Mono(sys) => sys.net.channel_profile(),
            FlowSim::Sharded(sys) => sys.sim.channel_profile(),
        }
    }

    /// [`MappedFlow::channel_profile`] re-keyed by **unit index** (PEs in
    /// registration order, then taps) — the currency
    /// [`FlowBuilder::profile_guided`] accepts, stable across rebuilds of
    /// the same flow even when auto-placement moves the endpoints.
    /// Traffic to endpoints hosting no named unit is dropped.
    pub fn unit_channel_profile(&self) -> ChannelProfile {
        let mut unit_of: BTreeMap<NodeId, u32> = BTreeMap::new();
        for (i, (_, node)) in
            self.pe_names.iter().chain(self.tap_names.iter()).enumerate()
        {
            unit_of.insert(*node, i as u32);
        }
        let mut out = ChannelProfile::new();
        for ((s, d), n) in self.channel_profile().iter() {
            if let (Some(&us), Some(&ud)) =
                (unit_of.get(&(s as usize)), unit_of.get(&(d as usize)))
            {
                out.add(us, ud, n);
            }
        }
        out
    }

    /// Run until the network is idle and every PE is drained; returns the
    /// unified report. Exceeding the cycle budget yields
    /// [`FlowError::Timeout`] instead of the low-level layer's panic.
    pub fn run(&mut self) -> Result<RunReport, FlowError> {
        let start = self.sim.cycle();
        while !self.sim.quiescent() {
            self.sim.step();
            // A latched wire fault keeps the lost frame pending forever;
            // surface it as a typed error instead of timing out.
            if let Some(MultiChipError::Corrupt { link, cycle }) = self.sim.wire_error() {
                return Err(FlowError::Link { link, cycle });
            }
            if self.sim.cycle() - start > self.max_cycles {
                return Err(FlowError::Timeout {
                    cycles: self.sim.cycle() - start,
                    pending: self.sim.pending(),
                });
            }
        }
        Ok(self.report(self.sim.cycle() - start))
    }

    /// Build one fresh flow per input, run it, and collect a value from
    /// the quiescent system — the batched-run primitive behind sweeps
    /// (BER curves, topology menus, r-sweeps). Serial; [`Sweep`] is the
    /// fleet-parallel counterpart with identical results.
    pub fn run_batch<I, T>(
        inputs: impl IntoIterator<Item = I>,
        mut build: impl FnMut(&I) -> Result<MappedFlow, FlowError>,
        mut collect: impl FnMut(&I, &mut MappedFlow) -> T,
    ) -> Result<Vec<(T, RunReport)>, FlowError> {
        let mut out = Vec::new();
        for input in inputs {
            let mut flow = build(&input)?;
            let report = flow.run()?;
            let value = collect(&input, &mut flow);
            out.push((value, report));
        }
        Ok(out)
    }

    /// The unified report for `cycles` elapsed (also computed by
    /// [`MappedFlow::run`]).
    pub fn report(&self, cycles: u64) -> RunReport {
        let mut report = match &self.sim {
            FlowSim::Mono(sys) => {
                let topo = sys.net.topo();
                let cfg = *sys.net.cfg();
                let resources_per_fpga = match &self.partition {
                    Some(p) => p.noc_resources_per_fpga(topo, &cfg, &self.serdes),
                    None => vec![topo.router_resources(&cfg)],
                };
                let serdes_flits =
                    sys.net.serdes_channels().map(|(_, c)| c.carried).sum();
                let serdes_cycles_per_flit = sys
                    .net
                    .serdes_channels()
                    .next()
                    .map_or(0, |(_, c)| c.ser_cycles);
                let pins_per_fpga = match &self.partition {
                    Some(p) => p.pins_per_fpga(topo, &self.serdes),
                    None => vec![0],
                };
                RunReport {
                    flow: self.name.clone(),
                    cycles,
                    net: sys.net.stats().clone(),
                    pes: Vec::new(),
                    n_fpgas: self.partition.as_ref().map_or(1, |p| p.n_fpgas),
                    cut_links: self.cut_links,
                    serdes_cycles_per_flit,
                    serdes_flits,
                    pins_per_fpga,
                    resources_per_fpga,
                    per_chip: Vec::new(),
                    links: Vec::new(),
                }
            }
            FlowSim::Sharded(sys) => {
                RunReport::from_multichip(&self.name, cycles, &sys.sim)
            }
        };
        // Per-PE stats, and wrapper/datapath resources onto the FPGA
        // hosting each PE.
        for ((name, node), res) in self.pe_names.iter().zip(&self.pe_resources) {
            let fpga = self.fpga_of(*node);
            report.resources_per_fpga[fpga] += *res;
            let wpe = self.sim.pe(*node).expect("PE attached at its endpoint");
            report.pes.push(PeRunStat {
                name: name.clone(),
                node: *node,
                fpga,
                invocations: wpe.invocations,
                busy_cycles: wpe.busy_cycles,
            });
        }
        report
    }

    /// Drain every flit ejected at a tap (raw host read).
    pub fn drain(&mut self, tap: &str) -> Vec<Flit> {
        let node = self.tap_node(tap);
        let mut out = Vec::new();
        while let Some(f) = self.sim.eject(node) {
            out.push(f);
        }
        out
    }

    /// Drain a tap and reassemble flits into `bits`-wide messages, one
    /// per (source, epoch, argument), sorted by (epoch, source, argument).
    pub fn drain_messages(&mut self, tap: &str, bits: usize) -> Vec<TapMessage> {
        let fw = self.sim.flit_width();
        let mut groups: BTreeMap<(u32, NodeId, u8), Vec<Flit>> = BTreeMap::new();
        for f in self.drain(tap) {
            let (epoch, arg) = split_tag(f.tag);
            groups.entry((epoch, f.src, arg)).or_default().push(f);
        }
        groups
            .into_iter()
            .map(|((epoch, src, arg), flits)| TapMessage {
                src,
                epoch,
                arg,
                words: depacketize(&flits, bits, fw),
            })
            .collect()
    }

    /// Host DMA readback of a named PE's result memory (the RIFFA path).
    pub fn readback(&self, pe: &str) -> Option<Vec<u64>> {
        let node = self
            .pe_names
            .iter()
            .find(|(n, _)| n.as_str() == pe)
            .map(|&(_, node)| node)?;
        self.sim.readback(node)
    }

    fn fpga_of(&self, node: NodeId) -> usize {
        match &self.partition {
            Some(p) => p.assignment[self.sim.endpoint_router(node)],
            None => 0,
        }
    }

    fn tap_node(&self, name: &str) -> NodeId {
        self.tap_names
            .iter()
            .find(|(n, _)| n.as_str() == name)
            .unwrap_or_else(|| panic!("flow '{}' has no tap '{name}'", self.name))
            .1
    }
}

/// Fleet-parallel flow sweeps: one fresh [`MappedFlow`] per input, built
/// and run on `threads` pooled workers ([`crate::fleet::run_jobs`]),
/// results returned **in input order** — bit-identical to
/// [`MappedFlow::run_batch`] over the same inputs for any thread count,
/// because every flow is deterministic and self-contained. This is the
/// design-exploration front end: BER curves, topology menus, partition
/// seeds, r-sweeps, each input one independent simulation.
///
/// Unlike `run_batch`, an error (build failure, run timeout) does not
/// cancel the other jobs — every input still runs to completion and the
/// error returned is deterministically the first one in INPUT order,
/// independent of scheduling. Pre-validate inputs if a sweep is
/// expensive enough that running past a failure matters.
pub struct Sweep {
    threads: usize,
}

impl Sweep {
    /// A sweep over `threads` workers (clamped to at least 1; use
    /// [`crate::fleet::default_threads`] for the machine's parallelism).
    pub fn new(threads: usize) -> Self {
        Sweep { threads: threads.max(1) }
    }

    /// Run one flow per input and collect `(collect(..), RunReport)` per
    /// input, in input order.
    pub fn run<I, T>(
        &self,
        inputs: &[I],
        build: impl Fn(&I) -> Result<MappedFlow, FlowError> + Sync,
        collect: impl Fn(&I, &mut MappedFlow) -> T + Sync,
    ) -> Result<Vec<(T, RunReport)>, FlowError>
    where
        I: Sync,
        T: Send,
    {
        let runs = crate::fleet::run_jobs(
            inputs,
            self.threads,
            |_| (),
            |_, input, _| -> Result<(T, RunReport), FlowError> {
                let mut flow = build(input)?;
                let report = flow.run()?;
                Ok((collect(input, &mut flow), report))
            },
        );
        runs.into_iter().collect()
    }
}

impl FlowBuilder {
    /// [`MappedFlow::run_batch`] on the fleet: build/run/collect one flow
    /// per input across `threads` workers. See [`Sweep`].
    pub fn run_sweep<I, T>(
        inputs: &[I],
        threads: usize,
        build: impl Fn(&I) -> Result<MappedFlow, FlowError> + Sync,
        collect: impl Fn(&I, &mut MappedFlow) -> T + Sync,
    ) -> Result<Vec<(T, RunReport)>, FlowError>
    where
        I: Sync,
        T: Send,
    {
        Sweep::new(threads).run(inputs, build, collect)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::noc::{Allocator, Flit};
    use crate::pe::collector::ArgMessage;
    use crate::pe::{MsgSink, OutMessage, WrapperSpec};

    /// Boot-time source sending fixed messages, then idle.
    struct Source {
        msgs: Vec<OutMessage>,
    }
    impl Processor for Source {
        fn spec(&self) -> WrapperSpec {
            WrapperSpec::new(vec![8], vec![16])
        }
        fn boot(&mut self, out: &mut MsgSink) {
            for m in std::mem::take(&mut self.msgs) {
                out.push(m);
            }
        }
        fn process(&mut self, _: &[ArgMessage], _: u32, _: &mut MsgSink) {}
    }

    /// adder(a, b) -> a + b, sent to `sink`.
    struct Adder {
        sink: NodeId,
        latency: u64,
    }
    impl Processor for Adder {
        fn spec(&self) -> WrapperSpec {
            WrapperSpec::new(vec![16, 16], vec![16])
        }
        fn latency(&self) -> u64 {
            self.latency
        }
        fn process(&mut self, args: &[ArgMessage], epoch: u32, out: &mut MsgSink) {
            let sum = (args[0].payload[0] + args[1].payload[0]) & 0xFFFF;
            out.word(self.sink, 0, epoch, sum, 16);
        }
    }

    fn source_msgs(epochs: u32, adder_at: NodeId) -> Vec<OutMessage> {
        (0..epochs)
            .flat_map(|e| {
                vec![
                    OutMessage::word(adder_at, 0, e, e as u64, 16),
                    OutMessage::word(adder_at, 1, e, 100, 16),
                ]
            })
            .collect()
    }

    #[test]
    fn flow_reproduces_legacy_pe_system_bit_for_bit() {
        // Legacy wiring (the pre-flow construction path).
        let mut sys = PeSystem::new(Network::new(
            &Topology::Mesh { w: 2, h: 2 },
            NocConfig::paper(),
        ));
        sys.attach(0, Box::new(Source { msgs: source_msgs(10, 3) }));
        sys.attach(3, Box::new(Adder { sink: 2, latency: 2 }));
        let legacy_cycles = sys.run(100_000);
        let mut legacy = Vec::new();
        while let Some(f) = sys.net.eject(2) {
            legacy.push((f.src, f.dst, f.tag, f.data));
        }

        // Same system through the flow API.
        let mut fb = FlowBuilder::new("adder");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("src", 0, Box::new(Source { msgs: source_msgs(10, 3) }))
            .pe_at("add", 3, Box::new(Adder { sink: 2, latency: 2 }))
            .tap_at("out", 2);
        let mut flow = fb.build().unwrap();
        let report = flow.run().unwrap();
        let got: Vec<_> = flow
            .drain("out")
            .into_iter()
            .map(|f| (f.src, f.dst, f.tag, f.data))
            .collect();
        assert_eq!(got, legacy, "flow must not change delivery");
        assert_eq!(report.cycles, legacy_cycles, "flow must not change timing");
        assert_eq!(report.total_invocations(), 10);
    }

    #[test]
    fn event_engine_flow_is_bit_identical_to_reference() {
        // Whole-flow conformance: wrapped PEs + partition + serdes on
        // both engines must agree on results AND timing.
        let run = |engine: SimEngine, partitioned: bool| {
            let mut fb = FlowBuilder::new("engines");
            fb.topology(Topology::Mesh { w: 2, h: 2 })
                .engine(engine)
                .pe_at("src", 0, Box::new(Source { msgs: source_msgs(12, 3) }))
                .pe_at("add", 3, Box::new(Adder { sink: 2, latency: 2 }))
                .tap_at("out", 2);
            if partitioned {
                fb.partition(Partition::new(2, vec![0, 0, 1, 1]));
            }
            let mut flow = fb.build().unwrap();
            let report = flow.run().unwrap();
            (report.cycles, report.net.clone(), flow.drain_messages("out", 16))
        };
        for partitioned in [false, true] {
            let reference = run(SimEngine::Reference, partitioned);
            let event = run(SimEngine::EventDriven, partitioned);
            assert_eq!(reference, event, "partitioned={partitioned}");
        }
    }

    #[test]
    fn report_carries_pe_stats_and_resources() {
        let mut fb = FlowBuilder::new("stats");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("src", 0, Box::new(Source { msgs: source_msgs(4, 3) }))
            .pe_at("add", 3, Box::new(Adder { sink: 2, latency: 5 }))
            .tap_at("out", 2)
            .pe_resources("add", Resources::new(64, 110));
        let mut flow = fb.build().unwrap();
        let report = flow.run().unwrap();
        let add = report.pes.iter().find(|p| p.name == "add").unwrap();
        assert_eq!(add.node, 3);
        assert_eq!(add.invocations, 4);
        assert_eq!(add.busy_cycles, 20);
        assert_eq!(report.n_fpgas, 1);
        assert_eq!(report.resources_per_fpga.len(), 1);
        // Routers + two wrappers + the declared datapath.
        let topo_only = (Topology::Mesh { w: 2, h: 2 })
            .build()
            .router_resources(&NocConfig::paper());
        assert!(report.resources_per_fpga[0].regs > topo_only.regs + 64);
        assert!(report.fits(&Device::ZC7020));
        assert!(format!("{report}").contains("flow 'stats'"));
    }

    #[test]
    fn partitioned_flow_same_results_more_cycles() {
        let build = |partitioned: bool| -> MappedFlow {
            let mut fb = FlowBuilder::new("cut");
            fb.topology(Topology::Mesh { w: 2, h: 2 })
                .pe_at("src", 0, Box::new(Source { msgs: source_msgs(8, 3) }))
                .pe_at("add", 3, Box::new(Adder { sink: 2, latency: 1 }))
                .tap_at("out", 2);
            if partitioned {
                fb.partition(Partition::new(2, vec![0, 0, 1, 1]));
            }
            fb.build().unwrap()
        };
        let mut mono = build(false);
        let mono_report = mono.run().unwrap();
        let mono_msgs = mono.drain_messages("out", 16);

        let mut split = build(true);
        let split_report = split.run().unwrap();
        let split_msgs = split.drain_messages("out", 16);

        assert_eq!(mono_msgs, split_msgs, "partitioning must not change results");
        assert!(split_report.cycles > mono_report.cycles);
        assert_eq!(split_report.n_fpgas, 2);
        assert!(split_report.cut_links > 0);
        assert!(split_report.serdes_flits > 0);
        assert!(split_report.serdes_cycles_per_flit > 0);
        assert_eq!(split_report.pins_per_fpga.len(), 2);
        assert_eq!(split_report.resources_per_fpga.len(), 2);
    }

    #[test]
    fn multichip_flow_same_messages_as_monolithic_partition() {
        // The same partitioned flow through the monolithic backend and
        // the sharded co-simulation: identical reassembled messages; the
        // sharded run carries per-chip stats and per-link occupancy.
        let build = |multichip: bool| -> MappedFlow {
            let mut fb = FlowBuilder::new("sharded");
            fb.topology(Topology::Mesh { w: 2, h: 2 })
                .pe_at("src", 0, Box::new(Source { msgs: source_msgs(10, 3) }))
                .pe_at("add", 3, Box::new(Adder { sink: 2, latency: 2 }))
                .tap_at("out", 2)
                .partition(Partition::new(2, vec![0, 0, 1, 1]));
            if multichip {
                fb.multichip(SerdesConfig::default());
            }
            fb.build().unwrap()
        };
        let mut mono = build(false);
        let mono_report = mono.run().unwrap();
        let mono_msgs = mono.drain_messages("out", 16);

        let mut sharded = build(true);
        let sharded_report = sharded.run().unwrap();
        let sharded_msgs = sharded.drain_messages("out", 16);

        assert_eq!(mono_msgs, sharded_msgs, "sharding must not change results");
        assert!(sharded_report.cycles >= mono_report.cycles);
        assert_eq!(sharded_report.n_fpgas, 2);
        assert_eq!(sharded_report.per_chip.len(), 2);
        assert!(!sharded_report.links.is_empty());
        assert!(sharded_report.links.iter().any(|l| l.carried > 0));
        assert!(sharded_report.serdes_flits > 0);
        assert_eq!(
            sharded_report.per_chip.iter().map(|s| s.delivered).sum::<u64>(),
            sharded_report.net.delivered
        );
        // Mono runs report no sharded extras.
        assert!(mono_report.per_chip.is_empty() && mono_report.links.is_empty());
        // PE stats flow through the sharded backend too.
        let add = sharded_report.pes.iter().find(|p| p.name == "add").unwrap();
        assert_eq!(add.invocations, 10);
        assert_eq!(add.fpga, 1);
    }

    #[test]
    fn protected_faulty_wires_recover_the_clean_messages() {
        // A seeded lossy fabric under CRC/retransmit protection must
        // produce exactly the clean flow's reassembled messages, paying
        // only in cycles.
        let build = |fault: Option<FaultPlan>| -> MappedFlow {
            let mut fb = FlowBuilder::new("lossy");
            fb.topology(Topology::Mesh { w: 2, h: 2 })
                .pe_at("src", 0, Box::new(Source { msgs: source_msgs(10, 3) }))
                .pe_at("add", 3, Box::new(Adder { sink: 2, latency: 2 }))
                .tap_at("out", 2)
                .partition(Partition::new(2, vec![0, 0, 1, 1]))
                .multichip(SerdesConfig::default());
            if let Some(p) = fault {
                fb.fault_plan(p);
            }
            fb.build().unwrap()
        };
        let mut clean = build(None);
        let clean_report = clean.run().unwrap();
        let clean_msgs = clean.drain_messages("out", 16);

        let plan = FaultPlan::new(0xD1CE).flips(0.01).drops(0.1);
        let mut lossy = build(Some(plan));
        let lossy_report = lossy.run().unwrap();
        let lossy_msgs = lossy.drain_messages("out", 16);

        assert_eq!(clean_msgs, lossy_msgs, "retransmission must hide the faults");
        assert!(lossy_report.cycles > clean_report.cycles, "recovery costs cycles");
        assert!(
            lossy_report.links.iter().any(|l| l.retransmitted > 0),
            "these rates must trigger replays: {:?}",
            lossy_report.links
        );
    }

    #[test]
    fn unprotected_faults_surface_as_a_typed_link_error() {
        let mut fb = FlowBuilder::new("unprot");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("src", 0, Box::new(Source { msgs: source_msgs(20, 3) }))
            .pe_at("add", 3, Box::new(Adder { sink: 2, latency: 2 }))
            .tap_at("out", 2)
            .partition(Partition::new(2, vec![0, 0, 1, 1]))
            .multichip(SerdesConfig::default())
            .fault_plan(FaultPlan::new(99).flips(0.05).unprotected());
        let mut flow = fb.build().unwrap();
        match flow.run() {
            Err(e @ FlowError::Link { .. }) => {
                assert!(format!("{e}").contains("unprotected wire link"));
            }
            other => panic!("expected a link error, got {other:?}"),
        }
    }

    #[test]
    fn fault_plan_without_multichip_is_a_layout_error() {
        let mut fb = FlowBuilder::new("nofault");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("src", 0, Box::new(Source { msgs: Vec::new() }))
            .partition(Partition::new(2, vec![0, 0, 1, 1]))
            .fault_plan(FaultPlan::new(1).flips(0.001));
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));
    }

    #[test]
    fn multichip_without_partition_is_a_layout_error() {
        let mut fb = FlowBuilder::new("nopart");
        fb.pe("p", Box::new(Source { msgs: Vec::new() }))
            .multichip(SerdesConfig::default());
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));
    }

    #[test]
    fn pin_together_keeps_units_on_one_fpga() {
        // The pfilter-root shape: a root PE and the tap collecting its
        // histograms must share a chip under auto-partitioning.
        let mut fb = FlowBuilder::new("pinned");
        fb.topology(Topology::Mesh { w: 4, h: 4 })
            .pe_at("root", 0, Box::new(Source { msgs: Vec::new() }))
            .tap_at("histo", 1)
            .auto_partition(2)
            .seed(5)
            .pin_together("root", "histo");
        let flow = fb.build().unwrap();
        let p = flow.partition().unwrap();
        assert_eq!(p.assignment[0], p.assignment[1], "pinned pair split across FPGAs");

        // Unpinned units cannot be pinned together (placement unknown).
        let mut fb = FlowBuilder::new("unpinned");
        fb.pe("a", Box::new(Source { msgs: Vec::new() }))
            .tap("t")
            .auto_partition(2)
            .pin_together("a", "t");
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));

        // A manual partition that splits a pinned pair is rejected, not
        // silently accepted.
        let mut fb = FlowBuilder::new("manual-split");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("root", 0, Box::new(Source { msgs: Vec::new() }))
            .tap_at("histo", 3)
            .partition(Partition::new(2, vec![0, 0, 1, 1]))
            .pin_together("root", "histo");
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));

        // ...while a manual partition that honors it builds fine.
        let mut fb = FlowBuilder::new("manual-ok");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("root", 0, Box::new(Source { msgs: Vec::new() }))
            .tap_at("histo", 1)
            .partition(Partition::new(2, vec![0, 0, 1, 1]))
            .pin_together("root", "histo");
        assert!(fb.build().is_ok());
    }

    #[test]
    fn auto_topology_auto_placement_and_auto_partition() {
        let mut fb = FlowBuilder::new("auto");
        // No topology, no endpoints: everything derived.
        fb.pe("src", Box::new(Source { msgs: Vec::new() }))
            .pe("add", Box::new(Adder { sink: 0, latency: 1 }))
            .tap("out")
            .channel("src", "add")
            .channel("add", "out")
            .auto_partition(2)
            .seed(7);
        let flow = fb.build().unwrap();
        // Feed the adder through the placed endpoints.
        let add = flow.node_of("add").unwrap();
        let out = flow.node_of("out").unwrap();
        assert_ne!(add, out);
        // Rebuild with a source that targets the placed endpoints.
        let mut fb2 = FlowBuilder::new("auto2");
        fb2.pe(
            "src",
            Box::new(Source {
                msgs: vec![
                    OutMessage::word(add, 0, 1, 5, 16),
                    OutMessage::word(add, 1, 1, 7, 16),
                ],
            }),
        )
        .pe_at("add", add, Box::new(Adder { sink: out, latency: 3 }))
        .tap_at("out", out)
        .channel("src", "add")
        .auto_partition(2)
        .seed(7);
        let mut flow2 = fb2.build().unwrap();
        let report = flow2.run().unwrap();
        let msgs = flow2.drain_messages("out", 16);
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].words[0], 12);
        assert_eq!(msgs[0].epoch, 1);
        assert_eq!(report.n_fpgas, 2);
    }

    #[test]
    fn profile_guided_placement_beats_static_on_a_hotspot_flow() {
        // A hotspot the declared graph hides: "src" (pinned, chip 0)
        // sends 40 messages to tap "hot" and 1 to tap "cold", but both
        // channels are declared weight 1 — the static placer cannot tell
        // them apart, and its deterministic tie-break hands the one
        // same-chip endpoint to "cold" (placed first), exiling the hot
        // stream across the serializing wire. A traced run measures the
        // real loads; re-building with profile_guided() must pull "hot"
        // back on-chip and strictly cut completion cycles.
        let hot_msgs: u32 = 40;
        let build = |measured: Option<ChannelProfile>,
                     targets: Option<(NodeId, NodeId)>|
         -> MappedFlow {
            let msgs = match targets {
                None => Vec::new(),
                Some((hot_ep, cold_ep)) => {
                    let mut m = vec![OutMessage::word(cold_ep, 0, 0, 7, 16)];
                    m.extend(
                        (0..hot_msgs)
                            .map(|e| OutMessage::word(hot_ep, 0, e, e as u64, 16)),
                    );
                    m
                }
            };
            let mut fb = FlowBuilder::new("hotspot");
            fb.topology(Topology::Mesh { w: 2, h: 2 })
                .pe_at("src", 0, Box::new(Source { msgs }))
                .tap("cold")
                .tap("hot")
                .channel("src", "cold")
                .channel("src", "hot")
                .partition(Partition::new(2, vec![0, 0, 1, 1]))
                .multichip(SerdesConfig::default());
            if let Some(p) = measured {
                fb.profile_guided(p);
            }
            fb.build().unwrap()
        };
        // Placement is independent of the boot messages, so a probe
        // build reveals where the taps land before wiring the sources.
        let probe = build(None, None);
        let static_eps =
            (probe.node_of("hot").unwrap(), probe.node_of("cold").unwrap());
        let mut static_flow = build(None, Some(static_eps));
        static_flow.enable_trace(1 << 12);
        let static_report = static_flow.run().unwrap();
        let profile = static_flow.unit_channel_profile();
        // Unit keys: pes first (src = 0), then taps (cold = 1, hot = 2).
        assert!(
            profile.get(0, 2) > profile.get(0, 1),
            "hot channel must measure heavier: {profile:?}"
        );

        let guided_probe = build(Some(profile.clone()), None);
        let guided_eps = (
            guided_probe.node_of("hot").unwrap(),
            guided_probe.node_of("cold").unwrap(),
        );
        assert_ne!(guided_eps.0, static_eps.0, "placement must actually move");
        let mut guided_flow = build(Some(profile), Some(guided_eps));
        let guided_report = guided_flow.run().unwrap();

        // The hot tap crossed back onto src's chip...
        let p = Partition::new(2, vec![0, 0, 1, 1]);
        let g = (Topology::Mesh { w: 2, h: 2 }).build();
        assert_eq!(p.assignment[g.endpoint_router(guided_eps.0)], 0);
        // ...and the measured loads strictly beat the static placement.
        assert!(
            guided_report.cycles < static_report.cycles,
            "guided {} !< static {}",
            guided_report.cycles,
            static_report.cycles
        );
        // Fewer flits serialized over the inter-chip wire, too.
        assert!(guided_report.serdes_flits < static_report.serdes_flits);
    }

    #[test]
    fn run_batch_builds_fresh_flows() {
        let runs = MappedFlow::run_batch(
            [1u64, 2, 3],
            |&x| {
                let mut fb = FlowBuilder::new("batch");
                fb.topology(Topology::Mesh { w: 2, h: 2 })
                    .pe_at(
                        "src",
                        0,
                        Box::new(Source {
                            msgs: vec![
                                OutMessage::word(3, 0, 0, x, 16),
                                OutMessage::word(3, 1, 0, 10, 16),
                            ],
                        }),
                    )
                    .pe_at("add", 3, Box::new(Adder { sink: 2, latency: 1 }))
                    .tap_at("out", 2);
                fb.build()
            },
            |_, flow| flow.drain_messages("out", 16)[0].words[0],
        )
        .unwrap();
        let sums: Vec<u64> = runs.iter().map(|(v, _)| *v).collect();
        assert_eq!(sums, vec![11, 12, 13]);
        assert!(runs.iter().all(|(_, r)| r.cycles > 0));
    }

    #[test]
    fn sweep_matches_run_batch_for_any_thread_count() {
        let build = |&x: &u64| {
            let mut fb = FlowBuilder::new("sweep");
            fb.topology(Topology::Mesh { w: 2, h: 2 })
                .pe_at(
                    "src",
                    0,
                    Box::new(Source {
                        msgs: vec![
                            OutMessage::word(3, 0, 0, x, 16),
                            OutMessage::word(3, 1, 0, 10, 16),
                        ],
                    }),
                )
                .pe_at("add", 3, Box::new(Adder { sink: 2, latency: 1 }))
                .tap_at("out", 2);
            fb.build()
        };
        let collect =
            |_: &u64, flow: &mut MappedFlow| flow.drain_messages("out", 16)[0].words[0];
        let inputs: Vec<u64> = (1..=9).collect();
        let serial = MappedFlow::run_batch(inputs.iter().copied(), |&x| build(&x), |&x, f| {
            collect(&x, f)
        })
        .unwrap();
        for threads in [1usize, 3, 8] {
            let swept = FlowBuilder::run_sweep(&inputs, threads, build, collect).unwrap();
            assert_eq!(swept.len(), serial.len());
            for (i, ((sv, sr), (pv, pr))) in serial.iter().zip(&swept).enumerate() {
                assert_eq!(sv, pv, "threads={threads} input {i}");
                assert_eq!(sr.cycles, pr.cycles, "threads={threads} input {i}");
                assert_eq!(sr.net, pr.net, "threads={threads} input {i}");
            }
        }
        // Errors propagate out of the fleet too.
        let bad = FlowBuilder::run_sweep(
            &inputs,
            2,
            |_| {
                let mut fb = FlowBuilder::new("bad");
                fb.noc(NocConfig { flit_data_width: 0, ..NocConfig::paper() })
                    .pe("p", Box::new(Source { msgs: Vec::new() }));
                fb.build()
            },
            |_, _| 0u64,
        );
        assert!(matches!(bad, Err(FlowError::Config(_))));
    }

    #[test]
    fn config_errors_are_results_not_panics() {
        let mut fb = FlowBuilder::new("bad");
        fb.noc(NocConfig { flit_data_width: 0, ..NocConfig::paper() })
            .pe("p", Box::new(Source { msgs: Vec::new() }));
        assert!(matches!(fb.build(), Err(FlowError::Config(_))));

        let mut fb = FlowBuilder::new("bad2");
        fb.noc(NocConfig {
            buffer_depth: 0,
            allocator: Allocator::SeparableInputFirstRR,
            ..NocConfig::paper()
        })
        .pe("p", Box::new(Source { msgs: Vec::new() }));
        assert!(matches!(fb.build(), Err(FlowError::Config(_))));
    }

    #[test]
    fn layout_errors_are_descriptive() {
        // Duplicate name.
        let mut fb = FlowBuilder::new("dup");
        fb.pe("x", Box::new(Source { msgs: Vec::new() }))
            .tap("x");
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));

        // Endpoint collision.
        let mut fb = FlowBuilder::new("collide");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("a", 1, Box::new(Source { msgs: Vec::new() }))
            .tap_at("t", 1);
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));

        // Endpoint out of range.
        let mut fb = FlowBuilder::new("range");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("a", 9, Box::new(Source { msgs: Vec::new() }));
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));

        // Too many units for the topology.
        let mut fb = FlowBuilder::new("full");
        fb.topology(Topology::Mesh { w: 2, h: 2 });
        for i in 0..5 {
            fb.pe(&format!("p{i}"), Box::new(Source { msgs: Vec::new() }));
        }
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));

        // Partition shaped for a different topology.
        let mut fb = FlowBuilder::new("shape");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("a", 0, Box::new(Source { msgs: Vec::new() }))
            .partition(Partition::new(2, vec![0, 1]));
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));

        // Channel to an unknown unit.
        let mut fb = FlowBuilder::new("chan");
        fb.pe("a", Box::new(Source { msgs: Vec::new() }))
            .channel("a", "ghost");
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));

        // No PEs at all.
        let mut fb = FlowBuilder::new("empty");
        fb.tap("t");
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));
    }

    #[test]
    fn second_build_is_an_error() {
        let mut fb = FlowBuilder::new("twice");
        fb.pe("p", Box::new(Source { msgs: Vec::new() }));
        assert!(fb.build().is_ok());
        assert!(matches!(fb.build(), Err(FlowError::Layout(_))));
    }

    #[test]
    fn timeout_is_a_result() {
        // An adder whose second argument never arrives stays non-quiescent
        // only if something keeps circulating — instead, exercise the
        // budget with a source that sends more work than the budget allows.
        let mut fb = FlowBuilder::new("slow");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("src", 0, Box::new(Source { msgs: source_msgs(50, 3) }))
            .pe_at("add", 3, Box::new(Adder { sink: 2, latency: 1000 }))
            .tap_at("out", 2)
            .max_cycles(100);
        let mut flow = fb.build().unwrap();
        match flow.run() {
            Err(FlowError::Timeout { cycles, .. }) => assert!(cycles > 100),
            other => panic!("expected timeout, got {other:?}"),
        }
    }

    #[test]
    fn drain_messages_reassembles_multiflit_messages() {
        // 48-bit messages cross the wrapper as 3 flits at width 16.
        struct Wide {
            sink: NodeId,
        }
        impl Processor for Wide {
            fn spec(&self) -> WrapperSpec {
                WrapperSpec::new(vec![48], vec![48])
            }
            fn process(&mut self, args: &[ArgMessage], epoch: u32, out: &mut MsgSink) {
                let p = out.message(self.sink, 0, epoch, 48);
                p.copy_from_slice(&args[0].payload);
                p[0] = p[0].wrapping_add(1) & 0xFFFF_FFFF_FFFF;
            }
        }
        let mut fb = FlowBuilder::new("wide");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at(
                "src",
                0,
                Box::new(Source {
                    msgs: vec![OutMessage {
                        dst: 3,
                        arg: 0,
                        epoch: 9,
                        payload: vec![0xAAAA_BBBB_CCCC],
                        bits: 48,
                    }],
                }),
            )
            .pe_at("wide", 3, Box::new(Wide { sink: 1 }))
            .tap_at("out", 1);
        let mut flow = fb.build().unwrap();
        flow.run().unwrap();
        let msgs = flow.drain_messages("out", 48);
        assert_eq!(msgs.len(), 1);
        assert_eq!(msgs[0].words, vec![0xAAAA_BBBB_CCCD]);
        assert_eq!(msgs[0].epoch, 9);
        assert_eq!(msgs[0].src, 3);
    }

    #[test]
    fn unknown_tap_panics_with_flow_name() {
        let mut fb = FlowBuilder::new("named");
        fb.pe("p", Box::new(Source { msgs: Vec::new() }));
        let mut flow = fb.build().unwrap();
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            flow.drain("ghost");
        }));
        assert!(err.is_err());
    }

    #[test]
    fn report_without_running_reflects_zero_cycles() {
        let mut fb = FlowBuilder::new("fresh");
        fb.pe("p", Box::new(Source { msgs: Vec::new() }));
        let flow = fb.build().unwrap();
        let report = flow.report(0);
        assert_eq!(report.cycles, 0);
        assert_eq!(report.total_invocations(), 0);
        assert_eq!(report.flow, "fresh");
    }

    #[test]
    fn eject_flit_fields_survive_the_flow_layer() {
        // drain() must hand back raw flits unchanged (the LDPC decoder
        // keys its decisions on f.src).
        let mut fb = FlowBuilder::new("raw");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at(
                "src",
                0,
                Box::new(Source { msgs: vec![OutMessage::word(2, 4, 7, 0xBEEF, 16)] }),
            )
            .tap_at("out", 2);
        let mut flow = fb.build().unwrap();
        flow.run().unwrap();
        let flits = flow.drain("out");
        assert_eq!(flits.len(), 1);
        let f: &Flit = &flits[0];
        assert_eq!((f.src, f.dst), (0, 2));
        assert_eq!(split_tag(f.tag), (7, 4));
        assert_eq!(f.data, 0xBEEF);
    }
}
