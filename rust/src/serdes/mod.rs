//! Quasi-SERDES link endpoints (paper §III, Fig 6).
//!
//! When the partitioner cuts an on-chip NoC link, the two halves keep
//! talking through a pair of serializer/deserializer endpoints implemented
//! over general-purpose FPGA pins — "quasi" because more than one pin
//! carries the serialized flit (the paper's example uses an 8-wire link).
//! The protocol (paper §III):
//!
//! > whenever a valid data (valid bit in the flit) is presented as input
//! > from the router keep it in buffer and start sending 8 bits at a time
//! > with MSB first; similarly, whenever a valid 8 bit MSB is received
//! > reconstruct output data and put the data on the output port to the
//! > router.
//!
//! [`SerdesChannel`] models one direction of such a link at cycle
//! granularity: a flit occupies the pins for
//! `ceil(flit_bits / pins) × clock_div` NoC cycles (`clock_div` models the
//! slower off-chip I/O clock), transfers are pipelined back-to-back, and a
//! bounded TX buffer back-pressures the router exactly like the paper's
//! "keep it in buffer". [`serialize_flit`]/[`deserialize_flit`] implement
//! the MSB-first wire format bit-exactly; the channel's timing model and
//! the wire format are cross-checked in tests.

use std::collections::VecDeque;

use crate::noc::flit::Flit;
use crate::resources::{self, Resources};
use crate::util::clog2;

/// Physical parameters of one quasi-SERDES link direction.
#[derive(Clone, Copy, Debug)]
pub struct SerdesConfig {
    /// FPGA pins (wires) carrying the serialized flit. Paper: 8.
    pub pins: u32,
    /// NoC clock cycles per pin transfer (off-chip I/O runs slower than
    /// the 100 MHz fabric; 1 = same clock).
    pub clock_div: u32,
    /// TX-side flit buffer depth ("keep it in buffer").
    pub tx_buffer: usize,
}

impl Default for SerdesConfig {
    fn default() -> Self {
        // The paper's example link: 8 wires; buffer mirrors the router's
        // flit buffer depth.
        SerdesConfig { pins: 8, clock_div: 1, tx_buffer: 8 }
    }
}

impl SerdesConfig {
    /// Cycles to serialize one flit of `flit_bits` total bits.
    pub fn cycles_per_flit(&self, flit_bits: u32) -> u64 {
        (flit_bits.div_ceil(self.pins) as u64) * self.clock_div as u64
    }

    /// FPGA cost of ONE endpoint (TX or RX side): shift register over the
    /// full flit, bit counter, pin drivers, valid/handshake FSM, and the
    /// TX flit buffer.
    pub fn endpoint_resources(&self, flit_bits: u32) -> Resources {
        resources::register(flit_bits)                       // shift register
            + resources::counter(clog2(flit_bits as usize).max(1)) // bit counter
            + resources::fsm(4)                              // idle/load/shift/present
            + resources::Resources::new(self.pins as u64, self.pins as u64) // pin IOB regs
            + resources::fifo(flit_bits, self.tx_buffer as u32)
    }
}

/// Total serialized bits of a flit on the wire: payload + header
/// (src, dst, tag, seq, last, vc) + valid bit. On the FPGA the header is
/// part of the CONNECT flit; we serialize the same information.
pub fn wire_bits(flit_data_width: u32, n_endpoints: usize) -> u32 {
    let id = clog2(n_endpoints.max(2));
    // valid + last + vc(2) + 2×endpoint id + tag(16) + seq(8) + payload
    1 + 1 + 2 + 2 * id + 16 + 8 + flit_data_width
}

/// Link-layer CRC field width. When a [`FaultPlan`] with `crc` enabled is
/// attached to the multi-chip fabric, each wire frame carries a
/// CRC-16-CCITT over the base frame, transmitted ahead of the valid bit,
/// and the RX gateway rejects (NAKs) frames whose check fails.
pub const CRC_BITS: u32 = 16;

/// [`wire_bits`] plus the optional link-layer CRC field.
pub fn wire_bits_ext(flit_data_width: u32, n_endpoints: usize, crc: bool) -> u32 {
    wire_bits(flit_data_width, n_endpoints) + if crc { CRC_BITS } else { 0 }
}

/// Words of the fixed stack bit-buffer the (de)serializers shift through
/// — 256 bits, comfortably above any supported wire format (≤ 64 payload
/// bits + header). The sharded co-simulation serializes every flit that
/// crosses a cut link, so this path must not allocate.
const WIRE_WORDS: usize = 4;

/// Write the low `n` bits of `v` at bit offset `at` of an LSB-first
/// packed word buffer.
#[inline]
fn put_bits(words: &mut [u64; WIRE_WORDS], at: usize, n: usize, v: u64) {
    if n == 0 {
        return;
    }
    let v = if n >= 64 { v } else { v & ((1u64 << n) - 1) };
    let (w, b) = (at / 64, at % 64);
    words[w] |= v << b;
    if b != 0 && b + n > 64 {
        words[w + 1] |= v >> (64 - b);
    }
}

/// Read `n` bits at bit offset `at` of an LSB-first packed word buffer.
#[inline]
fn get_bits(words: &[u64; WIRE_WORDS], at: usize, n: usize) -> u64 {
    if n == 0 {
        return 0;
    }
    let (w, b) = (at / 64, at % 64);
    let mut v = words[w] >> b;
    if b != 0 && b + n > 64 {
        v |= words[w + 1] << (64 - b);
    }
    if n < 64 {
        v &= (1u64 << n) - 1;
    }
    v
}

/// Pack a flit's fields into the wire bit layout
/// (LSB..: payload | seq | tag | dst | src | vc | last | valid).
fn pack_wire(f: &Flit, flit_data_width: u32, n_endpoints: usize) -> ([u64; WIRE_WORDS], usize) {
    let id = clog2(n_endpoints.max(2)) as usize;
    let total = wire_bits(flit_data_width, n_endpoints) as usize;
    assert!(total <= 64 * WIRE_WORDS, "wire format exceeds {} bits", 64 * WIRE_WORDS);
    let mut words = [0u64; WIRE_WORDS];
    let mut at = 0;
    put_bits(&mut words, at, flit_data_width as usize, f.data);
    at += flit_data_width as usize;
    put_bits(&mut words, at, 8, f.seq as u64);
    at += 8;
    put_bits(&mut words, at, 16, f.tag as u64);
    at += 16;
    put_bits(&mut words, at, id, f.dst as u64);
    at += id;
    put_bits(&mut words, at, id, f.src as u64);
    at += id;
    put_bits(&mut words, at, 2, f.vc as u64);
    at += 2;
    put_bits(&mut words, at, 1, f.last as u64);
    at += 1;
    put_bits(&mut words, at, 1, 1); // valid
    at += 1;
    debug_assert_eq!(at, total);
    (words, total)
}

/// CRC-16-CCITT (poly `0x1021`, init `0xFFFF`) over the low `n_bits` of
/// an LSB-first packed word buffer, consumed in wire (MSB-first) order.
/// The polynomial's `(x+1)` factor catches every odd-weight error and its
/// degree-15 primitive factor every 2-bit error up to 32767-bit frames —
/// far beyond the ≤256-bit wire format — which is the detection guarantee
/// the retransmit protocol leans on.
fn crc16_ccitt(words: &[u64; WIRE_WORDS], n_bits: usize) -> u16 {
    let mut crc: u16 = 0xFFFF;
    let mut pos = n_bits;
    while pos > 0 {
        pos -= 1;
        let bit = ((words[pos / 64] >> (pos % 64)) & 1) as u16;
        let top = (crc >> 15) ^ bit;
        crc <<= 1;
        if top & 1 == 1 {
            crc ^= 0x1021;
        }
    }
    crc
}

/// Emit the low `total` bits of `words` MSB-first as `pins`-bit samples
/// (last sample zero-padded) into a cleared `out`.
fn emit_samples(words: &[u64; WIRE_WORDS], total: usize, pins: u32, out: &mut Vec<u64>) {
    out.clear();
    out.reserve(total.div_ceil(pins as usize));
    let p = pins as usize;
    // MSB first: the first bit of each sample drives the highest pin.
    let mut pos = total;
    while pos > 0 {
        let mut s = 0u64;
        for i in 0..p {
            if pos == 0 {
                break;
            }
            pos -= 1;
            if (words[pos / 64] >> (pos % 64)) & 1 == 1 {
                s |= 1 << (p - 1 - i);
            }
        }
        out.push(s);
    }
}

/// Serialize a flit MSB-first into per-cycle pin samples (`pins` bits per
/// sample, last sample zero-padded), appended to a cleared `out` — the
/// zero-allocation form used by the multi-chip wire channels (pass a
/// pooled buffer whose capacity survives across flits). Bit-exact model
/// of the Fig 6 shifter.
pub fn serialize_flit_into(
    f: &Flit,
    flit_data_width: u32,
    n_endpoints: usize,
    pins: u32,
    out: &mut Vec<u64>,
) {
    serialize_flit_protected_into(f, flit_data_width, n_endpoints, pins, false, out)
}

/// [`serialize_flit_into`] with the optional link-layer CRC appended
/// (transmitted first, ahead of the valid bit). `crc = false` is
/// bit-identical to the unprotected format.
pub fn serialize_flit_protected_into(
    f: &Flit,
    flit_data_width: u32,
    n_endpoints: usize,
    pins: u32,
    crc: bool,
    out: &mut Vec<u64>,
) {
    assert!((1..=64).contains(&pins), "pins must be 1..=64, got {pins}");
    let (mut words, mut total) = pack_wire(f, flit_data_width, n_endpoints);
    if crc {
        assert!(total + CRC_BITS as usize <= 64 * WIRE_WORDS);
        let c = crc16_ccitt(&words, total);
        put_bits(&mut words, total, CRC_BITS as usize, c as u64);
        total += CRC_BITS as usize;
    }
    emit_samples(&words, total, pins, out);
}

/// Allocating convenience wrapper around [`serialize_flit_into`].
pub fn serialize_flit(f: &Flit, flit_data_width: u32, n_endpoints: usize, pins: u32) -> Vec<u64> {
    let mut samples = Vec::new();
    serialize_flit_into(f, flit_data_width, n_endpoints, pins, &mut samples);
    samples
}

/// Outcome of decoding a wire frame at the RX gateway.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WireDecode {
    /// A frame that passed every check.
    Flit(Flit),
    /// The valid bit is clear: no reconstructable frame is present.
    Invalid,
    /// The link-layer CRC check failed: corrupted in flight.
    Corrupt,
}

/// Reassemble a flit from pin samples, checking the link-layer CRC when
/// `crc` is set. Allocation-free (`injected_at` is a simulator artifact,
/// not wire data; it comes back 0).
pub fn decode_flit_protected(
    samples: &[u64],
    flit_data_width: u32,
    n_endpoints: usize,
    pins: u32,
    crc: bool,
) -> WireDecode {
    assert!((1..=64).contains(&pins), "pins must be 1..=64, got {pins}");
    let id = clog2(n_endpoints.max(2)) as usize;
    let base = wire_bits(flit_data_width, n_endpoints) as usize;
    let total = base + if crc { CRC_BITS as usize } else { 0 };
    assert!(total <= 64 * WIRE_WORDS, "wire format exceeds {} bits", 64 * WIRE_WORDS);
    let mut words = [0u64; WIRE_WORDS];
    // Undo MSB-first: sample 0 carries bits total-1 .. total-pins.
    let mut pos = total;
    'outer: for &s in samples {
        for i in 0..pins as usize {
            if pos == 0 {
                break 'outer;
            }
            pos -= 1;
            if (s >> (pins as usize - 1 - i)) & 1 == 1 {
                words[pos / 64] |= 1 << (pos % 64);
            }
        }
    }
    if crc {
        let stored = get_bits(&words, base, CRC_BITS as usize) as u16;
        if stored != crc16_ccitt(&words, base) {
            return WireDecode::Corrupt;
        }
    }
    let mut at = 0;
    let data = get_bits(&words, at, flit_data_width as usize);
    at += flit_data_width as usize;
    let seq = get_bits(&words, at, 8) as u32;
    at += 8;
    let tag = get_bits(&words, at, 16) as u32;
    at += 16;
    let dst = get_bits(&words, at, id) as usize;
    at += id;
    let src = get_bits(&words, at, id) as usize;
    at += id;
    let vc = get_bits(&words, at, 2) as u8;
    at += 2;
    let last = get_bits(&words, at, 1) == 1;
    at += 1;
    let valid = get_bits(&words, at, 1) == 1;
    if !valid {
        return WireDecode::Invalid;
    }
    WireDecode::Flit(Flit { src, dst, vc, tag, seq, last, data, injected_at: 0 })
}

/// Reassemble a flit from pin samples produced by [`serialize_flit`] /
/// [`serialize_flit_into`]. Returns `None` if the valid bit is clear.
pub fn deserialize_flit_from(
    samples: &[u64],
    flit_data_width: u32,
    n_endpoints: usize,
    pins: u32,
) -> Option<Flit> {
    match decode_flit_protected(samples, flit_data_width, n_endpoints, pins, false) {
        WireDecode::Flit(f) => Some(f),
        _ => None,
    }
}

/// Alias of [`deserialize_flit_from`] (kept for the original name).
pub fn deserialize_flit(
    samples: &[u64],
    flit_data_width: u32,
    n_endpoints: usize,
    pins: u32,
) -> Option<Flit> {
    deserialize_flit_from(samples, flit_data_width, n_endpoints, pins)
}

/// One direction of a cut link at cycle granularity. The router-side
/// output latch feeds [`SerdesChannel::push`]; [`SerdesChannel::pop_ready`]
/// yields flits whose serialization has completed.
#[derive(Clone, Debug)]
pub struct SerdesChannel {
    pub cfg: SerdesConfig,
    /// Serialization time for one flit, precomputed.
    pub ser_cycles: u64,
    /// (flit, cycle at which its last pin sample lands).
    queue: VecDeque<(Flit, u64)>,
    /// Pins busy until this cycle.
    busy_until: u64,
    /// Total flits carried (stats).
    pub carried: u64,
}

impl SerdesChannel {
    pub fn new(cfg: SerdesConfig, flit_bits: u32) -> Self {
        SerdesChannel {
            ser_cycles: cfg.cycles_per_flit(flit_bits),
            cfg,
            queue: VecDeque::new(),
            busy_until: 0,
            carried: 0,
        }
    }

    /// Is there TX buffer space for another flit?
    pub fn can_accept(&self) -> bool {
        self.queue.len() < self.cfg.tx_buffer
    }

    /// Drop all in-flight flits and counters in place (queue capacity
    /// retained) — the serdes half of [`crate::noc::Network::reset`].
    pub fn reset(&mut self) {
        self.queue.clear();
        self.busy_until = 0;
        self.carried = 0;
    }

    /// Accept a flit from the router at `cycle`; it completes transfer at
    /// `max(busy_until, cycle) + ser_cycles`.
    pub fn push(&mut self, flit: Flit, cycle: u64) {
        debug_assert!(self.can_accept());
        let start = self.busy_until.max(cycle);
        let done = start + self.ser_cycles;
        self.busy_until = done;
        self.queue.push_back((flit, done));
    }

    /// Pop the next flit whose transfer completed by `cycle`.
    pub fn pop_ready(&mut self, cycle: u64) -> Option<Flit> {
        if let Some(&(_, done)) = self.queue.front() {
            if done <= cycle {
                self.carried += 1;
                return self.queue.pop_front().map(|(f, _)| f);
            }
        }
        None
    }

    pub fn in_flight(&self) -> usize {
        self.queue.len()
    }

    /// Cycle at which the channel's next flit completes its transfer
    /// (`None` when nothing is in flight). The event-driven engine jumps
    /// the clock here when the whole network is otherwise frozen.
    pub fn next_ready(&self) -> Option<u64> {
        self.queue.front().map(|&(_, done)| done)
    }
}

/// One scheduled outage window, in absolute simulation cycles, half-open
/// `[from, until)`. A transfer whose last sample would land inside the
/// window is deferred until the window closes and then replayed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DownWindow {
    /// One directed cut link goes down (index into the fabric's link
    /// list, i.e. the order of `MultiChipSim::link_stats`).
    Link { link: usize, from: u64, until: u64 },
    /// A whole chip drops: every directed link into *or* out of the chip
    /// is down for the window.
    Chip { chip: usize, from: u64, until: u64 },
}

/// Seeded fault-injection plan for the inter-FPGA wire channels — the
/// "what happens when a link misbehaves?" knob the perfect-wire fabric
/// lacked. Attached via `MultiChipSim::set_fault_plan` or
/// `FlowBuilder::fault_plan`; each directed link derives an independent
/// RNG stream from `seed`, so runs are reproducible and identical across
/// schedulers and thread counts.
///
/// With `crc` enabled (the default once any corruption is configured)
/// the wire format grows a [`CRC_BITS`]-bit CRC and corrupt or dropped
/// frames are replayed from the TX buffer — delivery stays exactly-once
/// and in per-link FIFO order, only slower. With `crc` disabled
/// ([`FaultPlan::unprotected`]) corruption reaches the RX gateway
/// undetected: frames whose valid bit or routing fields are mangled
/// surface as a typed `Corrupt` run error instead of a panic.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// Master seed; per-link streams are derived from it.
    pub seed: u64,
    /// Per-transmitted-bit flip probability (applied to every pin sample
    /// of a frame, padding included).
    pub flip_rate: f64,
    /// Per-transfer whole-frame drop probability (the frame never
    /// arrives; the TX side times out and replays).
    pub drop_rate: f64,
    /// Protect frames with the link-layer CRC + retransmit protocol.
    pub crc: bool,
    /// Scheduled link/chip outage windows.
    pub down: Vec<DownWindow>,
}

impl FaultPlan {
    /// A plan that injects nothing — attaching it is bit-identical to
    /// attaching no plan at all.
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, flip_rate: 0.0, drop_rate: 0.0, crc: false, down: Vec::new() }
    }

    /// Flip each transmitted bit with probability `rate`; enables the
    /// CRC so corruption is detected and repaired by retransmission.
    pub fn flips(mut self, rate: f64) -> Self {
        self.flip_rate = rate;
        self.crc = true;
        self
    }

    /// Drop whole frames with probability `rate` per transfer (repaired
    /// by TX timeout + replay; no CRC needed to detect a missing frame).
    pub fn drops(mut self, rate: f64) -> Self {
        self.drop_rate = rate;
        self
    }

    /// Take one directed link down for `[from, until)`.
    pub fn link_down(mut self, link: usize, from: u64, until: u64) -> Self {
        self.down.push(DownWindow::Link { link, from, until });
        self
    }

    /// Take a whole chip down for `[from, until)` (all of its links).
    pub fn chip_down(mut self, chip: usize, from: u64, until: u64) -> Self {
        self.down.push(DownWindow::Chip { chip, from, until });
        self
    }

    /// Strip the CRC protection: corruption travels undetected and
    /// surfaces as a typed run error when it mangles a frame beyond
    /// reconstruction. For demonstrating *why* the link layer carries a
    /// CRC.
    pub fn unprotected(mut self) -> Self {
        self.crc = false;
        self
    }

    /// Does this plan inject anything at all? Trivial plans are dropped
    /// at attach time so the rate-0 axis of fault sweeps stays
    /// bit-identical to the clean fabric (no CRC bits, no RNG draws).
    pub fn is_trivial(&self) -> bool {
        self.flip_rate <= 0.0 && self.drop_rate <= 0.0 && self.down.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bits::BitVec;
    use crate::util::{prop, Rng};

    /// The original BitVec-based serializer, kept verbatim as the format
    /// oracle: the allocation-free stack-buffer path must emit the exact
    /// same pin samples.
    fn reference_serialize(
        f: &Flit,
        flit_data_width: u32,
        n_endpoints: usize,
        pins: u32,
    ) -> Vec<u64> {
        let id = clog2(n_endpoints.max(2)) as usize;
        let total = wire_bits(flit_data_width, n_endpoints) as usize;
        let mut bits = BitVec::zeros(total);
        let mut at = 0;
        bits.insert_u64(at, flit_data_width as usize, f.data);
        at += flit_data_width as usize;
        bits.insert_u64(at, 8, f.seq as u64);
        at += 8;
        bits.insert_u64(at, 16, f.tag as u64);
        at += 16;
        bits.insert_u64(at, id, f.dst as u64);
        at += id;
        bits.insert_u64(at, id, f.src as u64);
        at += id;
        bits.insert_u64(at, 2, f.vc as u64);
        at += 2;
        bits.insert_u64(at, 1, f.last as u64);
        at += 1;
        bits.insert_u64(at, 1, 1); // valid
        debug_assert_eq!(at + 1, total);
        let msb: Vec<bool> = bits.iter_msb_first().collect();
        let mut samples = Vec::new();
        for chunk in msb.chunks(pins as usize) {
            let mut s = 0u64;
            for (i, &b) in chunk.iter().enumerate() {
                if b {
                    s |= 1 << (pins as usize - 1 - i);
                }
            }
            samples.push(s);
        }
        samples
    }

    fn random_flit(rng: &mut Rng, n_eps: usize, width: u32) -> Flit {
        Flit {
            src: rng.index(n_eps),
            dst: rng.index(n_eps),
            vc: rng.index(4) as u8,
            tag: rng.next_u32() & 0xFFFF,
            seq: rng.index(256) as u32,
            last: rng.bool(),
            data: rng.next_u64() & if width >= 64 { u64::MAX } else { (1 << width) - 1 },
            injected_at: 0,
        }
    }

    #[test]
    fn stack_serializer_matches_bitvec_reference() {
        prop::check("serdes stack == BitVec reference", 300, |rng| {
            let n_eps = 2 + rng.index(200);
            let width = 1 + rng.index(64) as u32;
            // Non-divisor pin counts (7, 13, ...) included deliberately.
            let pins = 1 + rng.index(64) as u32;
            let f = random_flit(rng, n_eps, width);
            let fast = serialize_flit(&f, width, n_eps, pins);
            let slow = reference_serialize(&f, width, n_eps, pins);
            prop::assert_prop(
                fast == slow,
                format!("samples diverge (pins={pins} width={width} eps={n_eps}): {f:?}"),
            )
        });
    }

    #[test]
    fn serialize_into_reuses_the_buffer_and_roundtrips_pins_7() {
        // The multichip wire path: one pooled buffer, many flits, pins=7
        // (52 wire bits -> 8 samples, last one 4-bit padded).
        let mut buf = Vec::new();
        let mut cap = 0;
        for tag in 0..20u32 {
            let f = Flit { tag, ..Flit::single(3, 9, 0, 0x1234 + tag as u64) };
            serialize_flit_into(&f, 16, 16, 7, &mut buf);
            assert_eq!(buf.len(), (wire_bits(16, 16) as usize).div_ceil(7));
            let g = deserialize_flit_from(&buf, 16, 16, 7).expect("valid");
            assert_eq!((g.tag, g.data, g.src, g.dst), (tag, f.data, 3, 9));
            if tag == 0 {
                cap = buf.capacity();
            } else {
                assert_eq!(buf.capacity(), cap, "buffer must be reused, not regrown");
            }
        }
    }

    #[test]
    fn wire_format_roundtrip_randomized() {
        prop::check("serdes wire roundtrip", 200, |rng| {
            let n_eps = 2 + rng.index(62);
            let width = 8 + rng.index(25) as u32;
            let pins = 1 + rng.index(16) as u32;
            let f = Flit {
                src: rng.index(n_eps),
                dst: rng.index(n_eps),
                vc: rng.index(4) as u8,
                tag: rng.next_u32() & 0xFFFF,
                seq: rng.index(256) as u32,
                last: rng.bool(),
                data: rng.next_u64() & ((1 << width) - 1),
                injected_at: 0,
            };
            let samples = serialize_flit(&f, width, n_eps, pins);
            assert_eq!(
                samples.len(),
                (wire_bits(width, n_eps) as usize).div_ceil(pins as usize)
            );
            let g = deserialize_flit(&samples, width, n_eps, pins).expect("valid");
            prop::assert_prop(
                g.src == f.src
                    && g.dst == f.dst
                    && g.vc == f.vc
                    && g.tag == f.tag
                    && g.seq == f.seq
                    && g.last == f.last
                    && g.data == f.data,
                format!("{f:?} -> {g:?} (pins={pins} width={width})"),
            )
        });
    }

    #[test]
    fn invalid_wire_data_rejected() {
        // All-zero samples carry valid = 0.
        let zero = vec![0u64; 10];
        assert!(deserialize_flit(&zero, 16, 16, 8).is_none());
    }

    #[test]
    fn paper_link_timing_8_pins() {
        // Paper config: 16-bit payload, 16 endpoints, 8 wires.
        let bits = wire_bits(16, 16); // 1+1+2+8+16+8+16 = 52
        assert_eq!(bits, 52);
        let cfg = SerdesConfig::default();
        assert_eq!(cfg.cycles_per_flit(bits), 7); // ceil(52/8)
        let slow = SerdesConfig { clock_div: 4, ..cfg };
        assert_eq!(slow.cycles_per_flit(bits), 28);
    }

    #[test]
    fn channel_pipelines_back_to_back() {
        let cfg = SerdesConfig { pins: 8, clock_div: 1, tx_buffer: 4 };
        let mut ch = SerdesChannel::new(cfg, 52); // 7 cycles/flit
        ch.push(Flit::single(0, 1, 0, 1), 0);
        ch.push(Flit::single(0, 1, 1, 2), 0);
        assert!(ch.pop_ready(6).is_none());
        assert_eq!(ch.pop_ready(7).unwrap().data, 1);
        assert!(ch.pop_ready(13).is_none(), "second flit lands at 14");
        assert_eq!(ch.pop_ready(14).unwrap().data, 2);
        assert_eq!(ch.carried, 2);
    }

    #[test]
    fn channel_backpressures_at_tx_buffer() {
        let cfg = SerdesConfig { pins: 1, clock_div: 1, tx_buffer: 2 };
        let mut ch = SerdesChannel::new(cfg, 52);
        ch.push(Flit::single(0, 1, 0, 0), 0);
        ch.push(Flit::single(0, 1, 1, 0), 0);
        assert!(!ch.can_accept(), "buffer full");
        assert_eq!(ch.in_flight(), 2);
        let _ = ch.pop_ready(52).unwrap();
        assert!(ch.can_accept());
    }

    #[test]
    fn more_pins_serialize_faster() {
        let mut rng = Rng::new(3);
        let bits = wire_bits(16, 64);
        let mut last = u64::MAX;
        for pins in [1u32, 4, 8, 16] {
            let c = SerdesConfig { pins, clock_div: 1, tx_buffer: 8 }.cycles_per_flit(bits);
            assert!(c < last, "pins={pins}");
            last = c;
        }
        let _ = rng.next_u64();
    }

    #[test]
    fn endpoint_resources_nonzero_and_scale() {
        let small = SerdesConfig { pins: 4, clock_div: 1, tx_buffer: 4 }.endpoint_resources(52);
        let big = SerdesConfig { pins: 16, clock_div: 1, tx_buffer: 16 }.endpoint_resources(80);
        assert!(small.regs > 0 && small.luts > 0);
        assert!(big.regs > small.regs);
    }

    /// The meaningful (transmitted) bit positions of a protected frame:
    /// `(sample index, sample bit)` pairs, excluding the zero padding of
    /// the last sample which the receiver never reads.
    fn meaningful_bits(total: usize, pins: u32) -> Vec<(usize, u32)> {
        let p = pins as usize;
        let mut out = Vec::new();
        for j in 0..total.div_ceil(p) {
            for b in 0..p {
                if j * p + (p - 1 - b) < total {
                    out.push((j, b as u32));
                }
            }
        }
        out
    }

    #[test]
    fn crc_detects_all_1_and_2_bit_corruptions() {
        let (width, n_eps) = (16u32, 16usize);
        let total = wire_bits_ext(width, n_eps, true) as usize;
        assert_eq!(total, 52 + CRC_BITS as usize);
        for pins in [1u32, 7, 8, 32] {
            let f = Flit {
                vc: 1,
                tag: 0xBEE,
                seq: 3,
                last: false,
                ..Flit::single(5, 10, 0, 0xA5C3)
            };
            let mut clean = Vec::new();
            serialize_flit_protected_into(&f, width, n_eps, pins, true, &mut clean);
            assert_eq!(clean.len(), total.div_ceil(pins as usize));
            assert_eq!(
                decode_flit_protected(&clean, width, n_eps, pins, true),
                WireDecode::Flit(f),
                "clean protected frame must decode (pins={pins})"
            );
            let bits = meaningful_bits(total, pins);
            assert_eq!(bits.len(), total);
            // Every single-bit corruption is caught.
            for &(j, b) in &bits {
                let mut s = clean.clone();
                s[j] ^= 1 << b;
                let d = decode_flit_protected(&s, width, n_eps, pins, true);
                assert!(
                    !matches!(d, WireDecode::Flit(_)),
                    "1-bit flip slipped through (pins={pins} sample={j} bit={b})"
                );
            }
            // Every double-bit corruption is caught (CRC-16-CCITT
            // guarantee for frames below 32767 bits).
            for (i, &(j1, b1)) in bits.iter().enumerate() {
                for &(j2, b2) in &bits[i + 1..] {
                    let mut s = clean.clone();
                    s[j1] ^= 1 << b1;
                    s[j2] ^= 1 << b2;
                    let d = decode_flit_protected(&s, width, n_eps, pins, true);
                    assert!(
                        !matches!(d, WireDecode::Flit(_)),
                        "2-bit flip slipped through (pins={pins} \
                         ({j1},{b1})+({j2},{b2}))"
                    );
                }
            }
        }
    }

    #[test]
    fn protected_format_without_crc_is_bit_identical_to_base() {
        let mut rng = Rng::new(11);
        for _ in 0..50 {
            let n_eps = 2 + rng.index(100);
            let width = 1 + rng.index(60) as u32;
            let pins = 1 + rng.index(32) as u32;
            let f = random_flit(&mut rng, n_eps, width);
            let base = serialize_flit(&f, width, n_eps, pins);
            let mut prot = Vec::new();
            serialize_flit_protected_into(&f, width, n_eps, pins, false, &mut prot);
            assert_eq!(base, prot);
            // And the CRC frame is exactly CRC_BITS longer on the wire.
            assert_eq!(
                wire_bits_ext(width, n_eps, true),
                wire_bits(width, n_eps) + CRC_BITS
            );
        }
    }

    #[test]
    fn unprotected_corruption_travels_undetected() {
        // Without the CRC, a payload flip silently delivers wrong data
        // and a valid-bit flip makes the frame unreconstructable — the
        // two failure modes the typed Corrupt run error reports.
        let (width, n_eps, pins) = (16u32, 16usize, 8u32);
        let f = Flit::single(2, 7, 9, 0x1234);
        let clean = serialize_flit(&f, width, n_eps, pins);
        // Valid bit is the first transmitted bit: sample 0, highest pin.
        let mut s = clean.clone();
        s[0] ^= 1 << (pins - 1);
        assert_eq!(decode_flit_protected(&s, width, n_eps, pins, false), WireDecode::Invalid);
        // Payload bit 0 is the last transmitted bit of the frame.
        let total = wire_bits(width, n_eps) as usize;
        let last = (total - 1) / pins as usize;
        let bit = pins as usize - 1 - ((total - 1) % pins as usize);
        let mut s = clean.clone();
        s[last] ^= 1 << bit;
        match decode_flit_protected(&s, width, n_eps, pins, false) {
            WireDecode::Flit(g) => assert_eq!(g.data, f.data ^ 1, "silent corruption"),
            d => panic!("expected silently corrupted flit, got {d:?}"),
        }
    }

    #[test]
    fn fault_plan_builders() {
        let p = FaultPlan::new(7);
        assert!(p.is_trivial());
        assert!(!p.crc);
        let p = FaultPlan::new(7).flips(1e-3);
        assert!(!p.is_trivial());
        assert!(p.crc, "flips enable the CRC by default");
        assert!(FaultPlan::new(7).flips(0.0).is_trivial(), "rate 0 injects nothing");
        let p = FaultPlan::new(7).flips(1e-3).unprotected();
        assert!(!p.crc && !p.is_trivial());
        let p = FaultPlan::new(7).drops(0.01);
        assert!(!p.is_trivial());
        let p = FaultPlan::new(7).chip_down(1, 100, 300);
        assert_eq!(p.down, vec![DownWindow::Chip { chip: 1, from: 100, until: 300 }]);
        assert!(!p.is_trivial());
    }
}
