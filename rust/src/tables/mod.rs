//! Regeneration harness for every table in the paper's evaluation,
//! printing **model/measured vs paper** side by side (the experiment
//! index lives in DESIGN.md §3; measured results are recorded in
//! EXPERIMENTS.md).
//!
//! Hardware numbers come from the deterministic cycle-level simulator
//! (one run suffices — same inputs, same cycles) at the paper's 100 MHz
//! clock plus the RIFFA host-link model; software numbers are wall-clock
//! of the multithreaded baseline, averaged over `reps` runs (the paper
//! averaged 100; the default here is smaller and configurable).

use crate::apps::bmvm::{software, BmvmSystem, WilliamsLuts};
use crate::apps::ldpc::mapper::LdpcNocDecoder;
use crate::apps::ldpc::minsum::MinsumVariant;
use crate::apps::ldpc::nodes::{
    bit_node_resources, check_node_resources, wrapped_bit_node_resources,
    wrapped_check_node_resources,
};
use crate::apps::pfilter::pe::{pf_pe_bare_resources, pf_pe_noc_resources};
use crate::gf2::Gf2Matrix;
use crate::resources::Device;
use crate::util::bits::BitVec;
use crate::util::Rng;

/// Options shared by the table runners.
#[derive(Clone, Copy, Debug)]
pub struct TableOpts {
    /// Software-baseline repetitions to average (paper: 100).
    pub reps: usize,
    /// Drop the r = 1000 rows (CI-speed runs).
    pub quick: bool,
    /// Workload seed.
    pub seed: u64,
}

impl Default for TableOpts {
    fn default() -> Self {
        TableOpts { reps: 5, quick: false, seed: 0x7AB1E }
    }
}

fn fmt_row(cells: &[String], widths: &[usize]) -> String {
    cells
        .iter()
        .zip(widths)
        .map(|(c, w)| format!("{c:>w$}", w = w))
        .collect::<Vec<_>>()
        .join("  ")
}

/// Table I: resource utilization of computing nodes (bit/check node,
/// without and with wrapper) — model vs the paper's zc7020 synthesis.
pub fn table1() -> String {
    let bit = bit_node_resources(8);
    let bitw = wrapped_bit_node_resources(8, 3);
    let chk = check_node_resources(8);
    let chkw = wrapped_check_node_resources(8, 3);
    let d = Device::ZC7020;
    let mut out = String::from(
        "TABLE I: Resource utilization of computing nodes (model | paper)\n",
    );
    let w = [16, 10, 14, 14, 14, 14];
    out += &fmt_row(
        &[
            "resource".into(),
            "avail".into(),
            "bit w/o".into(),
            "bit w/".into(),
            "check w/o".into(),
            "check w/".into(),
        ],
        &w,
    );
    out.push('\n');
    out += &fmt_row(
        &[
            "slice regs".into(),
            d.regs.to_string(),
            format!("{} | 64", bit.regs),
            format!("{} | 297", bitw.regs),
            format!("{} | 40", chk.regs),
            format!("{} | 258", chkw.regs),
        ],
        &w,
    );
    out.push('\n');
    out += &fmt_row(
        &[
            "slice LUTs".into(),
            d.luts.to_string(),
            format!("{} | 110", bit.luts),
            format!("{} | 261", bitw.luts),
            format!("{} | 73", chk.luts),
            format!("{} | 199", chkw.luts),
        ],
        &w,
    );
    out.push('\n');
    out
}

/// Table II: whole LDPC design, monolithic vs NoC-mapped.
pub fn table2() -> String {
    let dec = LdpcNocDecoder::fano_on_mesh(MinsumVariant::PaperListing, 10);
    let mono = dec.monolithic_resources();
    let noc = dec.noc_resources();
    let d = Device::ZC7020;
    let (mf, ml, _, _) = d.utilization(mono);
    let (nf, nl, _, _) = d.utilization(noc);
    let mut out = String::from("TABLE II: Resource utilization of whole design (model | paper)\n");
    let w = [16, 10, 22, 26];
    out += &fmt_row(
        &["resource".into(), "avail".into(), "W/O wrapper".into(), "with NoC & wrapper".into()],
        &w,
    );
    out.push('\n');
    out += &fmt_row(
        &[
            "slice regs".into(),
            d.regs.to_string(),
            format!("{} ({mf}%) | 866 (1%)", mono.regs),
            format!("{} ({nf}%) | 1429 (1%)", noc.regs),
        ],
        &w,
    );
    out.push('\n');
    out += &fmt_row(
        &[
            "slice LUTs".into(),
            d.luts.to_string(),
            format!("{} ({ml}%) | 1370 (2%)", mono.luts),
            format!("{} ({nl}%) | 1384 (2%)", noc.luts),
        ],
        &w,
    );
    out.push('\n');
    out += "note: the paper's with-NoC total is below 14x its own Table I wrapped\n\
            cells (cross-module synthesis sharing); the model is compositional,\n\
            hence larger — see EXPERIMENTS.md E-T2.\n";
    out
}

/// Table III: one particle-filter PE.
pub fn table3() -> String {
    let bare = pf_pe_bare_resources(64, 48);
    let noc = pf_pe_noc_resources(64, 48);
    let d = Device::ZC7020;
    let (bf, bl, bd, _) = d.utilization(bare);
    let (nf, nl, nd, _) = d.utilization(noc);
    let mut out = String::from("TABLE III: Resource utilization of one PE (model | paper)\n");
    let w = [16, 10, 24, 26];
    out += &fmt_row(
        &["resource".into(), "avail".into(), "W/O wrapper".into(), "with NoC & wrapper".into()],
        &w,
    );
    out.push('\n');
    for (name, avail, got_b, got_n, p_b, p_n, pb_pct, pn_pct) in [
        ("slice regs", d.regs, bare.regs, noc.regs, 568u64, 2795u64, bf, nf),
        ("slice LUTs", d.luts, bare.luts, noc.luts, 1502, 3346, bl, nl),
        ("DSP48E", d.dsp, bare.dsp, noc.dsp, 1, 20, bd, nd),
    ] {
        out += &fmt_row(
            &[
                name.into(),
                avail.to_string(),
                format!("{got_b} ({pb_pct}%) | {p_b}"),
                format!("{got_n} ({pn_pct}%) | {p_n}"),
            ],
            &w,
        );
        out.push('\n');
    }
    out
}

/// Paper reference values for Table IV (ms).
pub const PAPER_T4: [(u32, f64, f64, f64); 4] = [
    (1, 0.32, 0.052, 6.15),
    (10, 1.1, 0.052, 21.15),
    (100, 5.2, 0.087, 59.8),
    (1000, 44.2, 0.58, 76.2),
];

/// One Table IV row.
#[derive(Clone, Debug)]
pub struct T4Row {
    pub r: u32,
    pub sw_ms: f64,
    pub hw_ms: f64,
    pub speedup: f64,
}

/// Run the Table IV experiment: n = 64, k = 8, f = 2, 4 PEs / 4 threads.
pub fn run_table4(opts: &TableOpts) -> Vec<T4Row> {
    let mut rng = Rng::new(opts.seed);
    let a = Gf2Matrix::random(64, 64, &mut rng);
    let luts = WilliamsLuts::preprocess(&a, 8);
    let v = BitVec::random(64, &mut rng);
    let sys = BmvmSystem::new(luts.clone(), 4, BmvmSystem::topology_for("mesh", 4));
    let rs: &[u32] = if opts.quick { &[1, 10, 100] } else { &[1, 10, 100, 1000] };
    rs.iter()
        .map(|&r| {
            let hw = sys.run(&v, r, None);
            let mut sw_total = 0.0;
            for _ in 0..opts.reps.max(1) {
                let sw = software::run_software(&luts, &v, r, 4);
                assert_eq!(sw.result, hw.result, "sw/hw disagree at r={r}");
                sw_total += sw.elapsed.as_secs_f64() * 1e3;
            }
            let sw_ms = sw_total / opts.reps.max(1) as f64;
            T4Row { r, sw_ms, hw_ms: hw.time_ms, speedup: sw_ms / hw.time_ms }
        })
        .collect()
}

/// Render Table IV with the paper's values alongside.
pub fn table4(opts: &TableOpts) -> String {
    let rows = run_table4(opts);
    let mut out = String::from(
        "TABLE IV: n=64, k=8, f=2, 4 PEs mesh vs 4-thread software (measured | paper)\n",
    );
    let w = [6, 24, 24, 24];
    out += &fmt_row(&["r".into(), "software ms".into(), "mesh ms".into(), "speedup".into()], &w);
    out.push('\n');
    for row in &rows {
        let paper = PAPER_T4.iter().find(|p| p.0 == row.r);
        let (ps, ph, pk) = paper.map(|p| (p.1, p.2, p.3)).unwrap_or((0.0, 0.0, 0.0));
        out += &fmt_row(
            &[
                row.r.to_string(),
                format!("{:.3} | {ps}", row.sw_ms),
                format!("{:.3} | {ph}", row.hw_ms),
                format!("{:.1} | {pk}", row.speedup),
            ],
            &w,
        );
        out.push('\n');
    }
    out
}

/// Paper reference values for Table V (ms): (r, sw, ring, mesh, torus, fat).
pub const PAPER_T5: [(u32, f64, f64, f64, f64, f64); 4] = [
    (1, 4.0, 0.205, 0.075, 0.060, 0.052),
    (10, 22.9, 1.67, 0.412, 0.299, 0.275),
    (100, 204.3, 16.15, 3.64, 2.83, 2.33),
    (1000, 2025.4, 160.51, 35.60, 28.09, 22.69),
];

/// One Table V row: times in ms for software + the four topologies.
#[derive(Clone, Debug)]
pub struct T5Row {
    pub r: u32,
    pub sw_ms: f64,
    pub topo_ms: [f64; 4], // ring, mesh, torus, fat_tree
}

pub const T5_TOPOS: [&str; 4] = ["ring", "mesh", "torus", "fat_tree"];

/// Run the Table V experiment: n = 1024, k = 4, f = 4, 64 PEs / threads.
pub fn run_table5(opts: &TableOpts) -> Vec<T5Row> {
    let mut rng = Rng::new(opts.seed ^ 5);
    let a = Gf2Matrix::random(1024, 1024, &mut rng);
    let luts = WilliamsLuts::preprocess(&a, 4);
    let v = BitVec::random(1024, &mut rng);
    let rs: &[u32] = if opts.quick { &[1, 10] } else { &[1, 10, 100, 1000] };
    rs.iter()
        .map(|&r| {
            let mut topo_ms = [0.0; 4];
            let mut expect = None;
            for (i, name) in T5_TOPOS.iter().enumerate() {
                let sys =
                    BmvmSystem::new(luts.clone(), 64, BmvmSystem::topology_for(name, 64));
                let run = sys.run(&v, r, None);
                if let Some(e) = &expect {
                    assert_eq!(e, &run.result, "{name} diverged");
                } else {
                    expect = Some(run.result.clone());
                }
                topo_ms[i] = run.time_ms;
            }
            let mut sw_total = 0.0;
            for _ in 0..opts.reps.max(1) {
                let sw = software::run_software(&luts, &v, r, 64);
                assert_eq!(&sw.result, expect.as_ref().unwrap());
                sw_total += sw.elapsed.as_secs_f64() * 1e3;
            }
            T5Row { r, sw_ms: sw_total / opts.reps.max(1) as f64, topo_ms }
        })
        .collect()
}

/// Render Table V with the paper's values alongside.
pub fn table5(opts: &TableOpts) -> String {
    let rows = run_table5(opts);
    let mut out = String::from(
        "TABLE V: n=1024, k=4, f=4, 64 PEs vs 64-thread software, time in ms \
         (measured | paper)\n",
    );
    let w = [6, 20, 20, 20, 20, 20];
    out += &fmt_row(
        &[
            "r".into(),
            "software".into(),
            "ring".into(),
            "mesh".into(),
            "torus".into(),
            "fat_tree".into(),
        ],
        &w,
    );
    out.push('\n');
    for row in &rows {
        let paper = PAPER_T5.iter().find(|p| p.0 == row.r);
        let p = paper.map(|p| [p.1, p.2, p.3, p.4, p.5]).unwrap_or_default();
        out += &fmt_row(
            &[
                row.r.to_string(),
                format!("{:.2} | {}", row.sw_ms, p[0]),
                format!("{:.3} | {}", row.topo_ms[0], p[1]),
                format!("{:.3} | {}", row.topo_ms[1], p[2]),
                format!("{:.3} | {}", row.topo_ms[2], p[3]),
                format!("{:.3} | {}", row.topo_ms[3], p[4]),
            ],
            &w,
        );
        out.push('\n');
    }
    out
}

/// Run every table (the `fabricflow tables --id all` path).
pub fn all_tables(opts: &TableOpts) -> String {
    let mut out = String::new();
    out += &table1();
    out.push('\n');
    out += &table2();
    out.push('\n');
    out += &table3();
    out.push('\n');
    out += &table4(opts);
    out.push('\n');
    out += &table5(opts);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_tables_render_with_paper_cells() {
        let t1 = table1();
        assert!(t1.contains("64") && t1.contains("297") && t1.contains("258"));
        let t2 = table2();
        assert!(t2.contains("866") && t2.contains("1370"));
        let t3 = table3();
        assert!(t3.contains("568") && t3.contains("2795") && t3.contains("20"));
    }

    #[test]
    fn table4_quick_shape_holds() {
        let opts = TableOpts { reps: 1, quick: true, seed: 1 };
        let rows = run_table4(&opts);
        assert_eq!(rows.len(), 3);
        // Hardware time grows with r but stays overhead-dominated early.
        assert!(rows[0].hw_ms <= rows[1].hw_ms);
        assert!(rows[1].hw_ms < rows[2].hw_ms);
        // The paper's headline: hardware beats software at every r.
        for row in &rows {
            assert!(row.speedup > 1.0, "r={} speedup {}", row.r, row.speedup);
        }
    }

    #[test]
    fn table5_quick_topology_ordering() {
        let opts = TableOpts { reps: 1, quick: true, seed: 2 };
        let rows = run_table5(&opts);
        let r10 = rows.iter().find(|r| r.r == 10).unwrap();
        // Ring is clearly slowest at r=10 (the paper's shape).
        assert!(r10.topo_ms[0] > r10.topo_ms[1]);
        assert!(r10.topo_ms[0] > r10.topo_ms[2]);
        assert!(r10.topo_ms[0] > r10.topo_ms[3]);
        // Mesh is never faster than torus.
        assert!(r10.topo_ms[1] >= r10.topo_ms[2]);
    }
}
