//! Phase-1 compiler-driven automation (paper §II-A-1, Fig 2): extract a
//! dataflow graph from a straight-line high-level description, partition
//! it, and (in [`crate::mips`]) compile the parts to a minimal MIPS
//! instruction set with network push/pull instructions.
//!
//! The input language is deliberately the paper's "straight line code":
//!
//! ```text
//! input a;
//! input b;
//! t1 = a + b;
//! t2 = a * 3;
//! y  = t1 ^ t2;
//! output y;
//! ```
//!
//! Operators: `+ - * & | ^ << >> min max` over u32 (wrapping). The DFG
//! nodes are inputs, constants and binary ops; [`Dfg::eval`] is the
//! sequential oracle, [`Dfg::partition`] assigns nodes to processors
//! level by level (respecting precedence so every cross-partition edge
//! becomes exactly one push/pull pair), and [`Dfg::levels`] is the ASAP
//! schedule the codegen orders instructions with.

use std::collections::HashMap;

/// Binary operators of the straight-line language.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    Add,
    Sub,
    Mul,
    And,
    Or,
    Xor,
    Shl,
    Shr,
    Min,
    Max,
}

impl Op {
    pub fn apply(self, a: u32, b: u32) -> u32 {
        match self {
            Op::Add => a.wrapping_add(b),
            Op::Sub => a.wrapping_sub(b),
            Op::Mul => a.wrapping_mul(b),
            Op::And => a & b,
            Op::Or => a | b,
            Op::Xor => a ^ b,
            Op::Shl => a.wrapping_shl(b & 31),
            Op::Shr => a.wrapping_shr(b & 31),
            Op::Min => a.min(b),
            Op::Max => a.max(b),
        }
    }

    fn parse(tok: &str) -> Option<Op> {
        Some(match tok {
            "+" => Op::Add,
            "-" => Op::Sub,
            "*" => Op::Mul,
            "&" => Op::And,
            "|" => Op::Or,
            "^" => Op::Xor,
            "<<" => Op::Shl,
            ">>" => Op::Shr,
            "min" => Op::Min,
            "max" => Op::Max,
            _ => return None,
        })
    }
}

/// A DFG node.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Node {
    /// External input (argument index).
    Input(usize),
    /// Constant.
    Const(u32),
    /// Binary operation over two earlier nodes.
    Bin(Op, usize, usize),
}

/// A dataflow graph extracted from straight-line code.
#[derive(Clone, Debug)]
pub struct Dfg {
    pub nodes: Vec<Node>,
    /// Node index of each declared output, with its name.
    pub outputs: Vec<(String, usize)>,
    /// Input names in argument order.
    pub inputs: Vec<String>,
}

/// Parse straight-line code (see module docs). Errors are returned as
/// human-readable strings (this is a build-time tool).
pub fn parse(src: &str) -> Result<Dfg, String> {
    let mut nodes = Vec::new();
    let mut env: HashMap<String, usize> = HashMap::new();
    let mut inputs = Vec::new();
    let mut outputs = Vec::new();
    for (lno, raw) in src.lines().enumerate() {
        let line = raw.split("//").next().unwrap().trim();
        if line.is_empty() {
            continue;
        }
        let line = line
            .strip_suffix(';')
            .ok_or_else(|| format!("line {}: missing ';'", lno + 1))?
            .trim();
        if let Some(name) = line.strip_prefix("input ") {
            let name = name.trim().to_string();
            if env.contains_key(&name) {
                return Err(format!("line {}: '{name}' redefined", lno + 1));
            }
            env.insert(name.clone(), nodes.len());
            nodes.push(Node::Input(inputs.len()));
            inputs.push(name);
        } else if let Some(name) = line.strip_prefix("output ") {
            let name = name.trim();
            let id = *env
                .get(name)
                .ok_or_else(|| format!("line {}: unknown output '{name}'", lno + 1))?;
            outputs.push((name.to_string(), id));
        } else {
            // name = a op b
            let (lhs, rhs) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected assignment", lno + 1))?;
            let lhs = lhs.trim().to_string();
            if env.contains_key(&lhs) {
                return Err(format!("line {}: '{lhs}' reassigned (SSA only)", lno + 1));
            }
            let toks: Vec<&str> = rhs.split_whitespace().collect();
            let operand = |tok: &str, nodes: &mut Vec<Node>| -> Result<usize, String> {
                if let Ok(c) = tok.parse::<u32>() {
                    nodes.push(Node::Const(c));
                    Ok(nodes.len() - 1)
                } else {
                    env.get(tok)
                        .copied()
                        .ok_or_else(|| format!("line {}: unknown name '{tok}'", lno + 1))
                }
            };
            let id = match toks.as_slice() {
                [a] => operand(a, &mut nodes)?,
                [a, op, b] => {
                    let op = Op::parse(op)
                        .ok_or_else(|| format!("line {}: bad operator '{op}'", lno + 1))?;
                    let ia = operand(a, &mut nodes)?;
                    let ib = operand(b, &mut nodes)?;
                    nodes.push(Node::Bin(op, ia, ib));
                    nodes.len() - 1
                }
                _ => return Err(format!("line {}: expected 'x = a op b'", lno + 1)),
            };
            env.insert(lhs, id);
        }
    }
    if outputs.is_empty() {
        return Err("no outputs declared".into());
    }
    Ok(Dfg { nodes, outputs, inputs })
}

impl Dfg {
    /// Sequential oracle: evaluate with the given input values.
    pub fn eval(&self, args: &[u32]) -> Vec<u32> {
        assert_eq!(args.len(), self.inputs.len());
        let mut vals = Vec::with_capacity(self.nodes.len());
        for n in &self.nodes {
            let v = match *n {
                Node::Input(i) => args[i],
                Node::Const(c) => c,
                Node::Bin(op, a, b) => op.apply(vals[a], vals[b]),
            };
            vals.push(v);
        }
        self.outputs.iter().map(|&(_, id)| vals[id]).collect()
    }

    /// ASAP level of each node (inputs/consts at level 0).
    pub fn levels(&self) -> Vec<usize> {
        let mut lv = vec![0usize; self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            if let Node::Bin(_, a, b) = *n {
                lv[i] = lv[a].max(lv[b]) + 1;
            }
        }
        lv
    }

    /// Partition nodes over `p` processors: level-ordered round-robin of
    /// the compute nodes (inputs/consts are co-located with their first
    /// consumer). Every cross-processor value edge becomes one
    /// push/pull pair in the generated code.
    pub fn partition(&self, p: usize) -> Vec<usize> {
        assert!(p >= 1);
        let lv = self.levels();
        let mut order: Vec<usize> = (0..self.nodes.len())
            .filter(|&i| matches!(self.nodes[i], Node::Bin(..)))
            .collect();
        order.sort_by_key(|&i| (lv[i], i));
        let mut assign = vec![usize::MAX; self.nodes.len()];
        for (pos, &i) in order.iter().enumerate() {
            assign[i] = pos % p;
        }
        // Leaves live with their first consumer (or proc 0 if unused).
        for i in 0..self.nodes.len() {
            if assign[i] != usize::MAX {
                continue;
            }
            let consumer = self.nodes.iter().enumerate().find_map(|(j, n)| match *n {
                Node::Bin(_, a, b) if a == i || b == i => Some(j),
                _ => None,
            });
            assign[i] = consumer.map(|j| assign[j]).unwrap_or(0);
        }
        assign
    }

    /// Cross-partition value edges (producer node, consumer node).
    pub fn cut_edges(&self, assign: &[usize]) -> Vec<(usize, usize)> {
        let mut cuts = Vec::new();
        for (j, n) in self.nodes.iter().enumerate() {
            if let Node::Bin(_, a, b) = *n {
                for src in [a, b] {
                    if assign[src] != assign[j] {
                        cuts.push((src, j));
                    }
                }
            }
        }
        cuts
    }
}

/// Generate a random straight-line program (shared by tests and the
/// randomized compiler benches).
pub fn random_program(rng: &mut crate::util::Rng, n_ops: usize) -> Dfg {
    assert!(n_ops >= 1);
    let n_in = 2 + rng.index(3);
    let mut src = String::new();
    for i in 0..n_in {
        src.push_str(&format!("input x{i};\n"));
    }
    let ops = ["+", "-", "*", "&", "|", "^", "min", "max"];
    let mut names: Vec<String> = (0..n_in).map(|i| format!("x{i}")).collect();
    for t in 0..n_ops {
        let a = rng.choose(&names).clone();
        let b = if rng.chance(0.2) {
            format!("{}", rng.below(100))
        } else {
            rng.choose(&names).clone()
        };
        let op = rng.choose(&ops);
        src.push_str(&format!("t{t} = {a} {op} {b};\n"));
        names.push(format!("t{t}"));
    }
    let n_out = 1 + rng.index(3.min(n_ops));
    for o in 0..n_out {
        src.push_str(&format!("output t{};\n", n_ops - 1 - o));
    }
    parse(&src).expect("generated program parses")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    const SAMPLE: &str = "
        input a;
        input b;
        t1 = a + b;     // sum
        t2 = a * 3;
        t3 = t1 min t2;
        y  = t3 ^ b;
        output y;
    ";

    #[test]
    fn parse_and_eval() {
        let g = parse(SAMPLE).unwrap();
        assert_eq!(g.inputs, vec!["a", "b"]);
        assert_eq!(g.outputs.len(), 1);
        // a=5, b=9: t1=14, t2=15, t3=14, y=14^9=7
        assert_eq!(g.eval(&[5, 9]), vec![7]);
    }

    #[test]
    fn parse_errors_are_informative() {
        assert!(parse("x = a + b;\noutput x;").unwrap_err().contains("unknown name"));
        assert!(parse("input a;\na = a + a;\noutput a;")
            .unwrap_err()
            .contains("reassigned"));
        assert!(parse("input a;").unwrap_err().contains("no outputs"));
        assert!(parse("input a\noutput a;").unwrap_err().contains("';'"));
    }

    #[test]
    fn levels_respect_precedence() {
        let g = parse(SAMPLE).unwrap();
        let lv = g.levels();
        for (j, n) in g.nodes.iter().enumerate() {
            if let Node::Bin(_, a, b) = *n {
                assert!(lv[j] > lv[a] && lv[j] > lv[b]);
            }
        }
    }

    #[test]
    fn partition_covers_all_and_cut_edges_are_real() {
        let g = parse(SAMPLE).unwrap();
        for p in 1..=4 {
            let assign = g.partition(p);
            assert!(assign.iter().all(|&x| x < p));
            let cuts = g.cut_edges(&assign);
            if p == 1 {
                assert!(cuts.is_empty());
            }
            for (s, d) in cuts {
                assert_ne!(assign[s], assign[d]);
            }
        }
    }

    #[test]
    fn random_programs_eval_deterministically() {
        prop::check("dfg eval deterministic", 20, |rng| {
            let g = random_program(rng, 20);
            let args: Vec<u32> = (0..g.inputs.len()).map(|_| rng.next_u32()).collect();
            prop::assert_prop(g.eval(&args) == g.eval(&args), "determinism")
        });
    }

    #[test]
    fn constants_fold_into_graph() {
        let g = parse("input a;\ny = a << 3;\noutput y;").unwrap();
        assert_eq!(g.eval(&[5]), vec![40]);
    }
}
