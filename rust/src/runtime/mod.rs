//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! Compiled only with the `pjrt` cargo feature: this module needs the
//! vendored `xla` crate and `anyhow`, which offline containers do not
//! ship (see Cargo.toml for how to enable it).
//!
//! Layer 2/3 seam of the three-layer architecture: `python/compile/aot.py`
//! lowers the JAX models (which call the Pallas kernels) to **HLO text**
//! under `artifacts/`; this module loads that text with
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client
//! and executes it from Rust — Python is never on the request path.
//!
//! HLO *text* (not a serialized proto) is the interchange format because
//! jax ≥ 0.5 emits 64-bit instruction ids that the crate's xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and DESIGN.md).
//!
//! The exported entry points and shapes (mirroring `aot.py`):
//!
//! | artifact | inputs | outputs |
//! |----------|--------|---------|
//! | `ldpc_fano_b16_i5`   | i32[16,7] LLRs | (i32[16,7] sums,) |
//! | `bmvm_pow_n64`       | u32[64,2] A, u32[2] v, i32 r | (u32[2],) |
//! | `pfilter_weights_n64`| i32[16] ref, i32[64,16] cands, i32[64,2] parts | (i64[2] center, i64[64] rho) |

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

/// Batch size of the LDPC artifact.
pub const LDPC_BATCH: usize = 16;
/// Iterations baked into the LDPC artifact.
pub const LDPC_NITER: u32 = 5;
/// Matrix dimension of the BMVM artifact.
pub const BMVM_N: usize = 64;
/// Particle count of the particle-filter artifact.
pub const PF_PARTICLES: usize = 64;
/// Histogram bins.
pub const PF_BINS: usize = 16;

/// Default artifacts directory: `$FABRICFLOW_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("FABRICFLOW_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// A PJRT CPU engine holding compiled executables.
pub struct XlaEngine {
    client: xla::PjRtClient,
}

/// One compiled artifact.
pub struct XlaExec {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl XlaEngine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("PJRT CPU client")?;
        Ok(XlaEngine { client })
    }

    /// Load + compile an HLO text artifact.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<XlaExec> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parse HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compile {}", path.display()))?;
        Ok(XlaExec {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
        })
    }

    /// Load a named artifact from [`artifacts_dir`].
    pub fn load_artifact(&self, name: &str) -> Result<XlaExec> {
        self.load_hlo(artifacts_dir().join(format!("{name}.hlo.txt")))
    }
}

impl XlaExec {
    /// Execute with literal inputs; returns the elements of the output
    /// tuple (aot.py lowers with `return_tuple=True`).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute::<xla::Literal>(inputs)?[0][0]
            .to_literal_sync()?;
        Ok(out.to_tuple()?)
    }
}

/// Build an i32 literal of the given dimensions from row-major data.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Build a u32 literal.
pub fn lit_u32(data: &[u32], dims: &[i64]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}

/// Scalar i32 literal.
pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

// ---------------------------------------------------------------------------
// Typed wrappers over the three artifacts
// ---------------------------------------------------------------------------

/// Batched LDPC decode via the AOT artifact.
pub struct XlaLdpcDecoder {
    exec: XlaExec,
}

impl XlaLdpcDecoder {
    pub fn load(engine: &XlaEngine) -> Result<Self> {
        Ok(XlaLdpcDecoder {
            exec: engine.load_artifact(&format!("ldpc_fano_b{LDPC_BATCH}_i{LDPC_NITER}"))?,
        })
    }

    /// Decode a batch of LLR rows (`batch x 7`, padded to [`LDPC_BATCH`]).
    /// Returns the final posterior sums per row.
    pub fn decode_batch(&self, llrs: &[[i32; 7]]) -> Result<Vec<[i32; 7]>> {
        assert!(llrs.len() <= LDPC_BATCH);
        let mut flat = vec![0i32; LDPC_BATCH * 7];
        for (i, row) in llrs.iter().enumerate() {
            flat[i * 7..(i + 1) * 7].copy_from_slice(row);
        }
        let input = lit_i32(&flat, &[LDPC_BATCH as i64, 7])?;
        let out = self.exec.run(&[input])?;
        let sums: Vec<i32> = out[0].to_vec()?;
        Ok(llrs
            .iter()
            .enumerate()
            .map(|(i, _)| {
                let mut row = [0i32; 7];
                row.copy_from_slice(&sums[i * 7..(i + 1) * 7]);
                row
            })
            .collect())
    }
}

/// Dense GF(2) A^r·v via the AOT artifact (the XLA-resident oracle the
/// BMVM hardware path is cross-checked against).
pub struct XlaBmvm {
    exec: XlaExec,
}

impl XlaBmvm {
    pub fn load(engine: &XlaEngine) -> Result<Self> {
        Ok(XlaBmvm { exec: engine.load_artifact(&format!("bmvm_pow_n{BMVM_N}"))? })
    }

    /// `a_rows` = packed rows of A (row-major, 2 u32 per row), `v` packed.
    pub fn power_matvec(&self, a_rows: &[u32], v: &[u32], r: i32) -> Result<Vec<u32>> {
        assert_eq!(a_rows.len(), BMVM_N * BMVM_N / 32);
        assert_eq!(v.len(), BMVM_N / 32);
        let a = lit_u32(a_rows, &[BMVM_N as i64, (BMVM_N / 32) as i64])?;
        let vv = lit_u32(v, &[(BMVM_N / 32) as i64])?;
        let out = self.exec.run(&[a, vv, lit_scalar_i32(r)])?;
        Ok(out[0].to_vec()?)
    }
}

/// Particle weighting + center update via the AOT artifact.
pub struct XlaPfWeights {
    exec: XlaExec,
}

impl XlaPfWeights {
    pub fn load(engine: &XlaEngine) -> Result<Self> {
        Ok(XlaPfWeights {
            exec: engine.load_artifact(&format!("pfilter_weights_n{PF_PARTICLES}"))?,
        })
    }

    /// Returns (center (x, y), rho per particle).
    pub fn weights(
        &self,
        ref_hist: &[i32; PF_BINS],
        cand_hists: &[[i32; PF_BINS]],
        particles: &[(i32, i32)],
    ) -> Result<((i64, i64), Vec<i64>)> {
        assert_eq!(cand_hists.len(), PF_PARTICLES);
        assert_eq!(particles.len(), PF_PARTICLES);
        let cands: Vec<i32> = cand_hists.iter().flatten().copied().collect();
        let parts: Vec<i32> = particles.iter().flat_map(|&(x, y)| [x, y]).collect();
        let out = self.exec.run(&[
            lit_i32(ref_hist.as_slice(), &[PF_BINS as i64])?,
            lit_i32(&cands, &[PF_PARTICLES as i64, PF_BINS as i64])?,
            lit_i32(&parts, &[PF_PARTICLES as i64, 2])?,
        ])?;
        let center: Vec<i64> = out[0].to_vec()?;
        let rho: Vec<i64> = out[1].to_vec()?;
        Ok(((center[0], center[1]), rho))
    }
}
