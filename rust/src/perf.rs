//! Tracked NoC benchmark matrix — the engine behind `fabricflow bench`
//! and `cargo bench --bench noc_engine`.
//!
//! Runs a fixed set of scenario-matrix points on **both** simulation
//! engines, cross-checks bit-identity in the same run, and reports
//! throughput in simulated **flits/sec** and **cycles/sec** of wall
//! clock. `fabricflow bench` serializes the result as `BENCH_noc.json`
//! so the perf trajectory of the simulator is tracked in-repo: refresh
//! the file after an optimization PR and the diff *is* the benchmark
//! history (see EXPERIMENTS.md §Performance).
//!
//! The acceptance headline for the zero-allocation core is
//! `saturated-mesh8x8/uniform`: at high offered load every router is
//! busy every cycle, so the run measures raw per-flit cost — buffer
//! layout, route lookup, allocator scratch — rather than idle-skip
//! cleverness (which the low-load points measure instead).

use std::time::Instant;

use crate::noc::multichip::MultiChipSim;
use crate::noc::scenario::{self, Trace};
use crate::noc::{NetStats, Network, NocConfig, SimEngine, Topology};
use crate::partition::Partition;
use crate::serdes::SerdesConfig;

/// One benchmark point: a scenario-matrix cell with a fixed seed.
#[derive(Clone, Debug)]
pub struct BenchPoint {
    /// Stable identifier used in JSON and printouts.
    pub label: &'static str,
    pub topo: Topology,
    pub scenario: &'static str,
    pub load: f64,
    /// Injection-window length in cycles.
    pub window: u64,
}

/// The tracked matrix. Labels are stable across PRs — tooling diffs
/// `BENCH_noc.json` by label.
pub fn points() -> Vec<BenchPoint> {
    vec![
        BenchPoint {
            label: "saturated-mesh8x8/uniform",
            topo: Topology::Mesh { w: 8, h: 8 },
            scenario: "uniform",
            load: 0.5,
            window: 4_000,
        },
        BenchPoint {
            label: "low-load-mesh8x8/uniform",
            topo: Topology::Mesh { w: 8, h: 8 },
            scenario: "uniform",
            load: 0.02,
            window: 30_000,
        },
        BenchPoint {
            label: "very-low-load-mesh8x8/uniform",
            topo: Topology::Mesh { w: 8, h: 8 },
            scenario: "uniform",
            load: 0.005,
            window: 30_000,
        },
        BenchPoint {
            label: "bursty-mesh8x8/bursty",
            topo: Topology::Mesh { w: 8, h: 8 },
            scenario: "bursty",
            load: 0.02,
            window: 30_000,
        },
        BenchPoint {
            label: "mid-load-torus8x8/uniform",
            topo: Topology::Torus { w: 8, h: 8 },
            scenario: "uniform",
            load: 0.2,
            window: 5_000,
        },
        BenchPoint {
            label: "hotspot-mesh8x8/hotspot",
            topo: Topology::Mesh { w: 8, h: 8 },
            scenario: "hotspot",
            load: 0.1,
            window: 5_000,
        },
        BenchPoint {
            label: "ldpc-trace-mesh4x4/ldpc-trace",
            topo: Topology::Mesh { w: 4, h: 4 },
            scenario: "ldpc-trace",
            load: 0.1,
            window: 20_000,
        },
    ]
}

/// One monolithic-vs-sharded comparison point: the same case-study trace
/// replayed on the whole-fabric `Network` and on the [`MultiChipSim`]
/// sharded across `n_fpgas` FPGAs at the paper's link parameters. The
/// tracked quantity is the **simulated-cycle slowdown** the quasi-serdes
/// links cost each case study (plus the wall-clock cost of co-simulating
/// the shards).
#[derive(Clone, Debug)]
pub struct MultiBenchPoint {
    pub label: &'static str,
    pub topo: Topology,
    pub scenario: &'static str,
    pub load: f64,
    pub window: u64,
    pub n_fpgas: usize,
    pub pins: u32,
    pub clock_div: u32,
}

/// The tracked monolithic-vs-sharded matrix: the three case-study
/// skeletons at the paper's 8-pin link, 2-way partitioned.
pub fn multichip_points() -> Vec<MultiBenchPoint> {
    let paper_link = |label, topo, scenario, window| MultiBenchPoint {
        label,
        topo,
        scenario,
        load: 0.1,
        window,
        n_fpgas: 2,
        pins: 8,
        clock_div: 1,
    };
    vec![
        paper_link(
            "ldpc-mesh4x4/2fpga-8pin",
            Topology::Mesh { w: 4, h: 4 },
            "ldpc-trace",
            5_000,
        ),
        paper_link(
            "pfilter-torus4x4/2fpga-8pin",
            Topology::Torus { w: 4, h: 4 },
            "pfilter-trace",
            5_000,
        ),
        paper_link(
            "bmvm-ring8/2fpga-8pin",
            Topology::Ring(8),
            "bmvm-trace",
            5_000,
        ),
    ]
}

/// Measured result of one (point, engine) cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub engine: SimEngine,
    /// Best-of-reps wall time for the full replay+drain, seconds.
    pub wall_s: f64,
    /// Flits injected (== delivered; cross-checked).
    pub flits: u64,
    /// Simulated cycles to drain.
    pub cycles: u64,
}

impl CellResult {
    pub fn flits_per_sec(&self) -> f64 {
        self.flits as f64 / self.wall_s
    }

    pub fn cycles_per_sec(&self) -> f64 {
        self.cycles as f64 / self.wall_s
    }
}

/// One point's results on both engines (stats proven identical).
#[derive(Clone, Debug)]
pub struct PointResult {
    pub label: &'static str,
    pub reference: CellResult,
    pub event: CellResult,
}

impl PointResult {
    /// Event-engine wall-clock speedup over the reference.
    pub fn speedup(&self) -> f64 {
        self.reference.wall_s / self.event.wall_s
    }
}

/// One multichip point's results: the same trace monolithic and sharded.
#[derive(Clone, Debug)]
pub struct MultiPointResult {
    pub label: &'static str,
    pub mono: CellResult,
    pub sharded: CellResult,
}

impl MultiPointResult {
    /// Simulated-cycle slowdown the quasi-serdes links cost (≥ 1).
    pub fn cycle_slowdown(&self) -> f64 {
        self.sharded.cycles as f64 / self.mono.cycles as f64
    }

    /// Wall-clock cost of co-simulating the shards vs one network.
    pub fn wall_ratio(&self) -> f64 {
        self.sharded.wall_s / self.mono.wall_s
    }
}

/// A full matrix run.
#[derive(Clone, Debug)]
pub struct BenchReport {
    /// `quick` profile (1 rep, shrunk windows) vs full (best of 3).
    pub quick: bool,
    pub points: Vec<PointResult>,
    /// Monolithic-vs-sharded slowdown per case study.
    pub multichip: Vec<MultiPointResult>,
}

/// One replay; the timer starts AFTER `Network::new` so construction
/// cost (route-table tabulation, arena zeroing) never skews the
/// per-flit throughput this file exists to track.
fn run_once(pt: &BenchPoint, engine: SimEngine, trace: &Trace) -> (f64, u64, NetStats) {
    let cfg = NocConfig { engine, ..NocConfig::paper() };
    let mut net = Network::new(&pt.topo, cfg);
    let t = Instant::now();
    let cycles = scenario::replay(&mut net, trace, 100_000_000)
        .unwrap_or_else(|e| panic!("{}: {e}", pt.label));
    let wall_s = t.elapsed().as_secs_f64();
    (wall_s, cycles, net.stats().clone())
}

/// Best-of-`reps` wall time plus the run digest (identical across reps:
/// the simulator is deterministic).
fn time_cell(
    pt: &BenchPoint,
    engine: SimEngine,
    trace: &Trace,
    reps: usize,
) -> (CellResult, (u64, NetStats)) {
    let mut best = f64::INFINITY;
    let mut digest = None;
    for _ in 0..reps {
        let (wall_s, cycles, stats) = run_once(pt, engine, trace);
        best = best.min(wall_s);
        digest = Some((cycles, stats));
    }
    let (cycles, stats) = digest.unwrap();
    assert_eq!(
        stats.injected, stats.delivered,
        "{}: lost flits under {engine:?}",
        pt.label
    );
    let cell = CellResult { engine, wall_s: best, flits: stats.delivered, cycles };
    (cell, (cycles, stats))
}

/// Run one point on both engines, asserting bit-identity of the digests
/// produced by the timed runs themselves (no extra untimed replay).
pub fn run_point(pt: &BenchPoint, reps: usize, window_scale: f64) -> PointResult {
    let scn = scenario::find(pt.scenario).expect("scenario registered");
    let n = pt.topo.build().n_endpoints;
    let window = ((pt.window as f64 * window_scale) as u64).max(100);
    let trace = scn.trace(n, pt.load, window, 1);
    let (reference, ref_digest) = time_cell(pt, SimEngine::Reference, &trace, reps);
    let (event, evt_digest) = time_cell(pt, SimEngine::EventDriven, &trace, reps);
    assert_eq!(
        ref_digest, evt_digest,
        "{}: engines disagree — conformance bug, numbers would be meaningless",
        pt.label
    );
    PointResult { label: pt.label, reference, event }
}

/// Run one monolithic-vs-sharded point (event engine on both sides;
/// the engines' own conformance is covered by [`run_point`]).
pub fn run_multichip_point(pt: &MultiBenchPoint, reps: usize, window_scale: f64) -> MultiPointResult {
    let scn = scenario::find(pt.scenario).expect("scenario registered");
    let graph = pt.topo.build();
    let n = graph.n_endpoints;
    let window = ((pt.window as f64 * window_scale) as u64).max(100);
    let trace = scn.trace(n, pt.load, window, 1);
    let cfg = NocConfig { engine: SimEngine::EventDriven, ..NocConfig::paper() };
    let partition = Partition::balanced(&graph, pt.n_fpgas, 1);
    let serdes = SerdesConfig { pins: pt.pins, clock_div: pt.clock_div, tx_buffer: 8 };

    let mut mono_best = f64::INFINITY;
    let mut mono_digest = (0u64, NetStats::default());
    for _ in 0..reps {
        let mut net = Network::new(&pt.topo, cfg);
        let t = Instant::now();
        let cycles = scenario::replay(&mut net, &trace, 100_000_000)
            .unwrap_or_else(|e| panic!("{} (mono): {e}", pt.label));
        mono_best = mono_best.min(t.elapsed().as_secs_f64());
        mono_digest = (cycles, net.stats().clone());
    }
    let mut sh_best = f64::INFINITY;
    let mut sh_digest = (0u64, NetStats::default());
    for _ in 0..reps {
        let mut sim = MultiChipSim::from_graph(graph.clone(), cfg, &partition, serdes);
        let t = Instant::now();
        let cycles = scenario::replay_multichip(&mut sim, &trace, 1_000_000_000)
            .unwrap_or_else(|e| panic!("{} (sharded): {e}", pt.label));
        sh_best = sh_best.min(t.elapsed().as_secs_f64());
        sh_digest = (cycles, sim.stats());
    }
    // Conformance: neither side lost flits, the shards followed the
    // monolithic routes (hop counts match), and serialization only adds.
    assert_eq!(mono_digest.1.injected, mono_digest.1.delivered, "{}", pt.label);
    assert_eq!(sh_digest.1.injected, sh_digest.1.delivered, "{}", pt.label);
    assert_eq!(mono_digest.1.delivered, sh_digest.1.delivered, "{}", pt.label);
    assert_eq!(mono_digest.1.link_hops, sh_digest.1.link_hops, "{}", pt.label);
    assert!(sh_digest.0 >= mono_digest.0, "{}: serdes made it faster?!", pt.label);
    MultiPointResult {
        label: pt.label,
        mono: CellResult {
            engine: SimEngine::EventDriven,
            wall_s: mono_best,
            flits: mono_digest.1.delivered,
            cycles: mono_digest.0,
        },
        sharded: CellResult {
            engine: SimEngine::EventDriven,
            wall_s: sh_best,
            flits: sh_digest.1.delivered,
            cycles: sh_digest.0,
        },
    }
}

/// Run the whole tracked matrix. `quick` shrinks windows 4x and uses one
/// rep — the CI perf-smoke profile.
pub fn run(quick: bool) -> BenchReport {
    let (reps, scale) = if quick { (1, 0.25) } else { (3, 1.0) };
    let points = points()
        .iter()
        .map(|pt| run_point(pt, reps, scale))
        .collect();
    let multichip = multichip_points()
        .iter()
        .map(|pt| run_multichip_point(pt, reps, scale))
        .collect();
    BenchReport { quick, points, multichip }
}

impl BenchReport {
    /// Serialize as stable, diffable JSON (hand-rolled: the default
    /// build has no dependencies).
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut j = String::new();
        let _ = writeln!(j, "{{");
        let _ = writeln!(j, "  \"schema\": \"fabricflow-bench-noc/v1\",");
        let _ = writeln!(j, "  \"profile\": \"{}\",", if self.quick { "quick" } else { "full" });
        let _ = writeln!(
            j,
            "  \"note\": \"regenerate with `cargo run --release -- bench{}`\",",
            if self.quick { " --quick" } else { "" }
        );
        let _ = writeln!(j, "  \"points\": [");
        for (i, p) in self.points.iter().enumerate() {
            let comma = if i + 1 == self.points.len() { "" } else { "," };
            let _ = writeln!(j, "    {{");
            let _ = writeln!(j, "      \"label\": \"{}\",", p.label);
            for (key, c) in [("reference", &p.reference), ("event", &p.event)] {
                let _ = writeln!(j, "      \"{key}\": {{");
                let _ = writeln!(j, "        \"flits\": {},", c.flits);
                let _ = writeln!(j, "        \"cycles\": {},", c.cycles);
                let _ = writeln!(j, "        \"wall_ms\": {:.3},", c.wall_s * 1e3);
                let _ = writeln!(j, "        \"flits_per_sec\": {:.0},", c.flits_per_sec());
                let _ = writeln!(j, "        \"cycles_per_sec\": {:.0}", c.cycles_per_sec());
                let _ = writeln!(j, "      }},");
            }
            let _ = writeln!(j, "      \"event_speedup\": {:.2}", p.speedup());
            let _ = writeln!(j, "    }}{comma}");
        }
        let _ = writeln!(j, "  ],");
        let _ = writeln!(j, "  \"multichip\": [");
        for (i, p) in self.multichip.iter().enumerate() {
            let comma = if i + 1 == self.multichip.len() { "" } else { "," };
            let _ = writeln!(j, "    {{");
            let _ = writeln!(j, "      \"label\": \"{}\",", p.label);
            for (key, c) in [("monolithic", &p.mono), ("sharded", &p.sharded)] {
                let _ = writeln!(j, "      \"{key}\": {{");
                let _ = writeln!(j, "        \"flits\": {},", c.flits);
                let _ = writeln!(j, "        \"cycles\": {},", c.cycles);
                let _ = writeln!(j, "        \"wall_ms\": {:.3},", c.wall_s * 1e3);
                let _ = writeln!(j, "        \"flits_per_sec\": {:.0}", c.flits_per_sec());
                let _ = writeln!(j, "      }},");
            }
            let _ = writeln!(j, "      \"cycle_slowdown\": {:.3},", p.cycle_slowdown());
            let _ = writeln!(j, "      \"wall_ratio\": {:.2}", p.wall_ratio());
            let _ = writeln!(j, "    }}{comma}");
        }
        let _ = writeln!(j, "  ]");
        let _ = writeln!(j, "}}");
        j
    }

    /// Human-readable table (the CLI and bench-binary printout).
    pub fn render_table(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "NoC benchmark matrix ({} profile; bit-identity asserted per point)",
            if self.quick { "quick" } else { "full" }
        );
        for p in &self.points {
            let _ = writeln!(
                s,
                "  {:32} {:>8} flits {:>9} cyc | ref {:>9.0} flit/s  event {:>9.0} flit/s  => {:.2}x",
                p.label,
                p.reference.flits,
                p.reference.cycles,
                p.reference.flits_per_sec(),
                p.event.flits_per_sec(),
                p.speedup()
            );
        }
        if !self.multichip.is_empty() {
            let _ = writeln!(s, "Monolithic vs sharded multi-FPGA (simulated-cycle slowdown)");
            for p in &self.multichip {
                let _ = writeln!(
                    s,
                    "  {:32} {:>8} flits | mono {:>9} cyc  sharded {:>9} cyc  => {:.2}x slower",
                    p.label,
                    p.mono.flits,
                    p.mono.cycles,
                    p.sharded.cycles,
                    p.cycle_slowdown()
                );
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_unique_and_scenarios_exist() {
        let pts = points();
        for (i, a) in pts.iter().enumerate() {
            assert!(scenario::find(a.scenario).is_some(), "{}", a.label);
            for b in &pts[i + 1..] {
                assert_ne!(a.label, b.label);
            }
        }
        assert!(pts.iter().any(|p| p.label == "saturated-mesh8x8/uniform"));
    }

    #[test]
    fn one_point_runs_and_serializes() {
        // Tiny profile of the headline point: engines must agree and the
        // JSON must carry its label and throughput fields.
        let pt = BenchPoint {
            label: "saturated-mesh8x8/uniform",
            topo: Topology::Mesh { w: 4, h: 4 },
            scenario: "uniform",
            load: 0.3,
            window: 200,
        };
        let res = run_point(&pt, 1, 1.0);
        assert!(res.reference.flits > 0);
        assert_eq!(res.reference.flits, res.event.flits);
        assert_eq!(res.reference.cycles, res.event.cycles);
        let report = BenchReport { quick: true, points: vec![res], multichip: Vec::new() };
        let json = report.to_json();
        assert!(json.contains("\"label\": \"saturated-mesh8x8/uniform\""));
        assert!(json.contains("flits_per_sec"));
        assert!(json.contains("\"profile\": \"quick\""));
        assert!(json.contains("\"multichip\": ["));
        assert!(report.render_table().contains("saturated-mesh8x8"));
    }

    #[test]
    fn multichip_labels_are_unique_and_scenarios_exist() {
        let pts = multichip_points();
        assert_eq!(pts.len(), 3, "one point per case study");
        for (i, a) in pts.iter().enumerate() {
            assert!(scenario::find(a.scenario).is_some(), "{}", a.label);
            for b in &pts[i + 1..] {
                assert_ne!(a.label, b.label);
            }
        }
    }

    #[test]
    fn multichip_point_runs_and_serializes() {
        // A shrunk bmvm point: the sharded run must deliver the same
        // flit count, cost at least as many cycles, and serialize into
        // the multichip JSON section.
        let pt = MultiBenchPoint {
            label: "bmvm-ring8/2fpga-8pin",
            topo: Topology::Ring(8),
            scenario: "bmvm-trace",
            load: 0.1,
            window: 400,
            n_fpgas: 2,
            pins: 8,
            clock_div: 1,
        };
        let res = run_multichip_point(&pt, 1, 1.0);
        assert!(res.mono.flits > 0);
        assert_eq!(res.mono.flits, res.sharded.flits);
        assert!(res.cycle_slowdown() >= 1.0);
        let report =
            BenchReport { quick: true, points: Vec::new(), multichip: vec![res] };
        let json = report.to_json();
        assert!(json.contains("\"label\": \"bmvm-ring8/2fpga-8pin\""));
        assert!(json.contains("cycle_slowdown"));
        assert!(report.render_table().contains("sharded"));
    }
}
