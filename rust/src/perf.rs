//! Tracked NoC benchmark matrix — the engine behind `fabricflow bench`
//! and `cargo bench --bench noc_engine`.
//!
//! Runs a fixed set of scenario-matrix points on **both** simulation
//! engines, cross-checks bit-identity in the same run, and reports
//! throughput in simulated **flits/sec** and **cycles/sec** of wall
//! clock. `fabricflow bench` serializes the result as `BENCH_noc.json`
//! so the perf trajectory of the simulator is tracked in-repo: refresh
//! the file after an optimization PR and the diff *is* the benchmark
//! history (see EXPERIMENTS.md §Performance).
//!
//! The acceptance headline for the zero-allocation core is
//! `saturated-mesh8x8/uniform`: at high offered load every router is
//! busy every cycle, so the run measures raw per-flit cost — buffer
//! layout, route lookup, allocator scratch — rather than idle-skip
//! cleverness (which the low-load points measure instead).

use std::time::Instant;

use crate::fleet;
use crate::noc::multichip::MultiChipSim;
use crate::noc::scenario::{self, SweepGrid, Trace};
use crate::noc::{NetStats, Network, NocConfig, SharedFabric, SimEngine, Topology};
use crate::partition::Partition;
use crate::serdes::{FaultPlan, SerdesConfig};
use crate::serve::{self, loadgen};

/// One benchmark point: a scenario-matrix cell with a fixed seed.
#[derive(Clone, Debug)]
pub struct BenchPoint {
    /// Stable identifier used in JSON and printouts.
    pub label: &'static str,
    pub topo: Topology,
    pub scenario: &'static str,
    pub load: f64,
    /// Injection-window length in cycles.
    pub window: u64,
}

/// The tracked matrix. Labels are stable across PRs — tooling diffs
/// `BENCH_noc.json` by label.
pub fn points() -> Vec<BenchPoint> {
    vec![
        BenchPoint {
            label: "saturated-mesh8x8/uniform",
            topo: Topology::Mesh { w: 8, h: 8 },
            scenario: "uniform",
            load: 0.5,
            window: 4_000,
        },
        BenchPoint {
            label: "low-load-mesh8x8/uniform",
            topo: Topology::Mesh { w: 8, h: 8 },
            scenario: "uniform",
            load: 0.02,
            window: 30_000,
        },
        BenchPoint {
            label: "very-low-load-mesh8x8/uniform",
            topo: Topology::Mesh { w: 8, h: 8 },
            scenario: "uniform",
            load: 0.005,
            window: 30_000,
        },
        BenchPoint {
            label: "bursty-mesh8x8/bursty",
            topo: Topology::Mesh { w: 8, h: 8 },
            scenario: "bursty",
            load: 0.02,
            window: 30_000,
        },
        BenchPoint {
            label: "mid-load-torus8x8/uniform",
            topo: Topology::Torus { w: 8, h: 8 },
            scenario: "uniform",
            load: 0.2,
            window: 5_000,
        },
        BenchPoint {
            label: "hotspot-mesh8x8/hotspot",
            topo: Topology::Mesh { w: 8, h: 8 },
            scenario: "hotspot",
            load: 0.1,
            window: 5_000,
        },
        BenchPoint {
            label: "ldpc-trace-mesh4x4/ldpc-trace",
            topo: Topology::Mesh { w: 4, h: 4 },
            scenario: "ldpc-trace",
            load: 0.1,
            window: 20_000,
        },
    ]
}

/// One monolithic-vs-sharded comparison point: the same case-study trace
/// replayed on the whole-fabric `Network` and on the [`MultiChipSim`]
/// sharded across `n_fpgas` FPGAs at the paper's link parameters. The
/// tracked quantity is the **simulated-cycle slowdown** the quasi-serdes
/// links cost each case study (plus the wall-clock cost of co-simulating
/// the shards).
#[derive(Clone, Debug)]
pub struct MultiBenchPoint {
    pub label: &'static str,
    pub topo: Topology,
    pub scenario: &'static str,
    pub load: f64,
    pub window: u64,
    pub n_fpgas: usize,
    pub pins: u32,
    pub clock_div: u32,
}

/// The tracked monolithic-vs-sharded matrix: the three case-study
/// skeletons at the paper's 8-pin link, 2-way partitioned.
pub fn multichip_points() -> Vec<MultiBenchPoint> {
    let paper_link = |label, topo, scenario, window| MultiBenchPoint {
        label,
        topo,
        scenario,
        load: 0.1,
        window,
        n_fpgas: 2,
        pins: 8,
        clock_div: 1,
    };
    vec![
        paper_link(
            "ldpc-mesh4x4/2fpga-8pin",
            Topology::Mesh { w: 4, h: 4 },
            "ldpc-trace",
            5_000,
        ),
        paper_link(
            "pfilter-torus4x4/2fpga-8pin",
            Topology::Torus { w: 4, h: 4 },
            "pfilter-trace",
            5_000,
        ),
        paper_link(
            "bmvm-ring8/2fpga-8pin",
            Topology::Ring(8),
            "bmvm-trace",
            5_000,
        ),
    ]
}

/// Measured result of one (point, engine) cell.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub engine: SimEngine,
    /// Best-of-reps wall time for the full replay+drain, seconds.
    pub wall_s: f64,
    /// Flits injected (== delivered; cross-checked).
    pub flits: u64,
    /// Simulated cycles to drain.
    pub cycles: u64,
}

impl CellResult {
    pub fn flits_per_sec(&self) -> f64 {
        self.flits as f64 / self.wall_s
    }

    pub fn cycles_per_sec(&self) -> f64 {
        self.cycles as f64 / self.wall_s
    }
}

/// One point's results on both engines (stats proven identical).
#[derive(Clone, Debug)]
pub struct PointResult {
    pub label: &'static str,
    pub reference: CellResult,
    pub event: CellResult,
}

impl PointResult {
    /// Event-engine wall-clock speedup over the reference.
    pub fn speedup(&self) -> f64 {
        self.reference.wall_s / self.event.wall_s
    }
}

/// One multichip point's results: the same trace monolithic and sharded.
#[derive(Clone, Debug)]
pub struct MultiPointResult {
    pub label: &'static str,
    pub mono: CellResult,
    pub sharded: CellResult,
}

impl MultiPointResult {
    /// Simulated-cycle slowdown the quasi-serdes links cost (≥ 1).
    pub fn cycle_slowdown(&self) -> f64 {
        self.sharded.cycles as f64 / self.mono.cycles as f64
    }

    /// Wall-clock cost of co-simulating the shards vs one network.
    pub fn wall_ratio(&self) -> f64 {
        self.sharded.wall_s / self.mono.wall_s
    }
}

/// Measured fleet throughput: the `"sweep"` section of
/// `BENCH_noc.json`. Two tracked quantities: the **job-level speedup**
/// of running one sweep grid on N workers vs 1 (thread-count invariance
/// of the results is asserted inside the same run), and the
/// **construct-once-vs-rebuild speedup** of `SharedFabric` + `reset()`
/// over a fresh `Network::new` per job.
#[derive(Clone, Debug)]
pub struct SweepBench {
    /// Cells in the throughput grid.
    pub grid_jobs: usize,
    /// Worker threads of the parallel run.
    pub threads: usize,
    pub serial_jobs_per_sec: f64,
    pub parallel_jobs_per_sec: f64,
    /// `parallel_jobs_per_sec / serial_jobs_per_sec` (the ISSUE's
    /// "jobs/sec at 1 vs N threads" headline).
    pub parallel_speedup: f64,
    /// Jobs of the reuse-vs-rebuild comparison.
    pub reuse_jobs: usize,
    pub rebuild_jobs_per_sec: f64,
    pub reuse_jobs_per_sec: f64,
    /// `reuse_jobs_per_sec / rebuild_jobs_per_sec`.
    pub reuse_speedup: f64,
}

/// One offered-load point of the serving benchmark: a seeded open-loop
/// loadgen stream paced through [`serve::serve_stream`] in-process.
#[derive(Clone, Debug)]
pub struct ServePoint {
    pub label: String,
    /// Offered rate, requests/sec (`0.0` = flood, no pacing).
    pub offered_rps: f64,
    pub requests: u64,
    pub served: u64,
    pub rejected: u64,
    pub achieved_rps: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    pub rejection_rate: f64,
}

/// The `"serve"` section of `BENCH_noc.json`: service latency
/// percentiles, throughput, and rejection rate vs offered load on the
/// warm replica pool. Request *bytes* are deterministic in the loadgen
/// seed; latencies and the flood point's rejection split are wall-clock
/// measurements (unbaselined, like every other timing in the file).
#[derive(Clone, Debug)]
pub struct ServeBench {
    pub threads: usize,
    pub queue_cap: usize,
    pub points: Vec<ServePoint>,
}

/// One fault-rate point of the `"faults"` benchmark section.
#[derive(Clone, Debug)]
pub struct FaultPoint {
    /// Per-sample-bit flip AND per-flit drop probability of the seeded
    /// plan (0 = clean links, no CRC).
    pub rate: f64,
    /// Completion cycle of the replay at this rate.
    pub cycles: u64,
    pub delivered: u64,
    /// Wire-level replays (CRC NAKs + drop timeouts) summed over links.
    pub retransmits: u64,
    /// Frames the RX CRC rejected, summed over links.
    pub corrupted: u64,
    /// Delivered flits per simulated cycle.
    pub goodput: f64,
    /// `cycles / clean_cycles` (the rate-0 row is exactly 1.0).
    pub overhead: f64,
}

/// The `"faults"` section of `BENCH_noc.json`: goodput and
/// completion-cycle overhead vs wire fault rate on a bisected mesh under
/// CRC/retransmit protection. Every row delivers the identical message
/// set (asserted in the same run) — only the cost changes. Nonzero rows
/// pay the CRC stretch of the wire format plus the replays themselves.
#[derive(Clone, Debug)]
pub struct FaultsBench {
    pub scenario: &'static str,
    pub pins: u32,
    pub clock_div: u32,
    pub points: Vec<FaultPoint>,
}

/// One lane-count point of the `"bitsliced"` benchmark section.
#[derive(Clone, Debug)]
pub struct BitslicedPoint {
    pub lanes: usize,
    /// Monte-Carlo seeds decoded per wall-second by the scalar reference
    /// decoder looped over the lane seeds.
    pub scalar_seeds_per_sec: f64,
    /// The same seeds through the bitsliced decoder — one code traversal
    /// carries every lane.
    pub sliced_seeds_per_sec: f64,
    /// `sliced_seeds_per_sec / scalar_seeds_per_sec`.
    pub speedup: f64,
}

/// The `"bitsliced"` section of `BENCH_noc.json`: scalar-vs-sliced LDPC
/// Monte-Carlo throughput (seeds/sec) at 1, 8 and 64 lanes. Lane
/// results are asserted bit-identical to the scalar loop inside the same
/// run, so the speedup column never trades correctness; at 64 lanes the
/// sliced path must not lose to the scalar loop (the whole point of
/// packing 64 simulations per machine word).
#[derive(Clone, Debug)]
pub struct BitslicedBench {
    pub code: &'static str,
    pub variant: &'static str,
    pub frames: usize,
    pub niter: u32,
    pub points: Vec<BitslicedPoint>,
}

/// The `"trace"` section of `BENCH_noc.json`: what the opt-in flit
/// recorder costs, and what its measurements buy. Two halves, both
/// correctness-asserted in the same run: the hotspot scenario replayed
/// with the recorder off and on (run digests must be bit-identical —
/// tracing observes, never steers; only wall clock moves), and the
/// closed measure → re-place loop: a 2-chip flow whose declared channel
/// weights hide a hotspot, re-placed from the traced
/// [`crate::noc::ChannelProfile`] via `FlowBuilder::profile_guided`,
/// which must strictly cut the
/// completion cycles of the static placement.
#[derive(Clone, Debug)]
pub struct TraceBench {
    /// Scenario of the overhead point.
    pub scenario: &'static str,
    /// Completion cycles of the overhead replay (identical traced and
    /// untraced — asserted in the same run).
    pub cycles: u64,
    /// Events the traced replay recorded (ring wraps don't subtract:
    /// this is the monotone recorder count, not the survivor count).
    pub events: u64,
    pub untraced_wall_ms: f64,
    pub traced_wall_ms: f64,
    /// `traced_wall_ms / untraced_wall_ms` — the wall-clock price of
    /// the recorder for the same simulated work.
    pub trace_overhead: f64,
    /// Completion cycles of the statically placed hotspot flow.
    pub static_cycles: u64,
    /// Completion cycles after one `profile_guided` re-placement.
    pub guided_cycles: u64,
    /// `static_cycles / guided_cycles` (> 1: the measured loads won).
    pub guided_speedup: f64,
}

/// The `"optimize"` section of `BENCH_noc.json`: design-space autopilot
/// throughput. The same small topology × pins × depth space is searched
/// twice — sequential exhaustive evaluation at one worker, then the
/// racing path (successive-halving prunes + memoized fabrics + fleet
/// fan-out) at N workers — and the racing front is asserted
/// **byte-identical** to the exhaustive one in the same run, with
/// strictly fewer full-budget launches. The tracked quantity is
/// points-resolved/sec on each path; the speedup column is what the
/// capped prune path + memoization + threads buy without changing a
/// single answer.
#[derive(Clone, Debug)]
pub struct OptimizeBench {
    pub scenario: &'static str,
    /// Configurations in the searched space.
    pub space_points: usize,
    /// Worker threads of the racing run (exhaustive times at 1).
    pub threads: usize,
    /// Pareto-front size (identical on both paths — asserted).
    pub front_size: usize,
    pub exhaustive_full_runs: usize,
    pub racing_full_runs: usize,
    pub racing_probe_runs: usize,
    pub racing_pruned: usize,
    /// Space points resolved per wall-second, exhaustive at 1 thread.
    pub sequential_evals_per_sec: f64,
    /// Space points resolved per wall-second, racing at `threads`.
    pub racing_evals_per_sec: f64,
    /// `racing_evals_per_sec / sequential_evals_per_sec`.
    pub racing_speedup: f64,
}

/// Run the autopilot benchmark (the `"optimize"` section): one 2-chip
/// search space evaluated exhaustively at a single worker, then raced
/// through the capped prune path at N workers. Front equality and the
/// saved full-budget runs are asserted here, in the run that produces
/// the numbers — the speedup column never trades exactness.
pub fn run_optimize_bench(quick: bool) -> OptimizeBench {
    use crate::optimize::{self, OptimizeSetup};
    use crate::space::{SearchSpace, TopoSpec};

    let topos = if quick {
        vec![TopoSpec::Mesh { w: 2, h: 2 }]
    } else {
        vec![TopoSpec::Mesh { w: 2, h: 2 }, TopoSpec::Mesh { w: 4, h: 4 }]
    };
    let space = SearchSpace {
        topos,
        pins: vec![1, 8],
        clock_divs: vec![1],
        buffer_depths: if quick { vec![8] } else { vec![4, 8] },
        part_seeds: vec![1],
        chips: 2,
        pinned: Vec::new(),
    };
    let scn = scenario::find("uniform").expect("scenario registered");
    let window = if quick { 300 } else { 1_000 };
    let setup = OptimizeSetup::new(space, scn, 0.1, window);

    let mut seq_setup = setup.clone();
    seq_setup.threads = 1;
    let t = Instant::now();
    let ex = optimize::exhaustive(&seq_setup).expect("optimize bench (exhaustive)");
    let seq_s = t.elapsed().as_secs_f64();

    let threads = fleet::default_threads().max(2);
    let mut race_setup = setup;
    race_setup.threads = threads;
    let t = Instant::now();
    let ra = optimize::race(&race_setup).expect("optimize bench (racing)");
    let race_s = t.elapsed().as_secs_f64();

    assert_eq!(
        ex.front, ra.front,
        "racing front diverged from exhaustive — the speedup would be meaningless"
    );
    assert!(
        ra.full_runs < ex.full_runs,
        "racing saved no full-budget runs ({} vs {})",
        ra.full_runs,
        ex.full_runs
    );
    let points = ex.space_points as f64;
    OptimizeBench {
        scenario: "uniform",
        space_points: ex.space_points,
        threads,
        front_size: ex.front.len(),
        exhaustive_full_runs: ex.full_runs,
        racing_full_runs: ra.full_runs,
        racing_probe_runs: ra.probe_runs,
        racing_pruned: ra.pruned,
        sequential_evals_per_sec: points / seq_s,
        racing_evals_per_sec: points / race_s,
        racing_speedup: seq_s / race_s,
    }
}

/// Which `BENCH_noc.json` sections a bench invocation regenerates
/// (`fabricflow bench --only points|multichip|sweep|serve|faults|bitsliced|trace|optimize`);
/// unselected sections are preserved from the existing file by
/// [`merge_sections`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BenchSelect {
    pub points: bool,
    pub multichip: bool,
    pub sweep: bool,
    pub serve: bool,
    pub faults: bool,
    pub bitsliced: bool,
    pub trace: bool,
    pub optimize: bool,
}

impl BenchSelect {
    /// Every section (the default `fabricflow bench`).
    pub const ALL: BenchSelect = BenchSelect {
        points: true,
        multichip: true,
        sweep: true,
        serve: true,
        faults: true,
        bitsliced: true,
        trace: true,
        optimize: true,
    };

    /// No section — the base [`BenchSelect::parse`] builds on.
    pub const NONE: BenchSelect = BenchSelect {
        points: false,
        multichip: false,
        sweep: false,
        serve: false,
        faults: false,
        bitsliced: false,
        trace: false,
        optimize: false,
    };

    /// Parse a comma-separated `--only` value.
    pub fn parse(s: &str) -> Option<BenchSelect> {
        let mut sel = BenchSelect::NONE;
        for part in s.split(',') {
            match part.trim() {
                "points" => sel.points = true,
                "multichip" => sel.multichip = true,
                "sweep" => sel.sweep = true,
                "serve" => sel.serve = true,
                "faults" => sel.faults = true,
                "bitsliced" => sel.bitsliced = true,
                "trace" => sel.trace = true,
                "optimize" => sel.optimize = true,
                _ => return None,
            }
        }
        Some(sel)
    }

    pub fn is_all(&self) -> bool {
        *self == Self::ALL
    }
}

/// A full matrix run.
#[derive(Clone, Debug)]
pub struct BenchReport {
    /// `quick` profile (1 rep, shrunk windows) vs full (best of 3).
    pub quick: bool,
    pub points: Vec<PointResult>,
    /// Monolithic-vs-sharded slowdown per case study.
    pub multichip: Vec<MultiPointResult>,
    /// Fleet sweep throughput (None when the section was not run).
    pub sweep: Option<SweepBench>,
    /// Serving latency vs offered load (None when the section was not
    /// run).
    pub serve: Option<ServeBench>,
    /// Goodput/overhead vs wire fault rate (None when the section was
    /// not run).
    pub faults: Option<FaultsBench>,
    /// Scalar-vs-bitsliced Monte-Carlo throughput (None when the section
    /// was not run).
    pub bitsliced: Option<BitslicedBench>,
    /// Trace-recorder overhead and the profile-guided placement win
    /// (None when the section was not run).
    pub trace: Option<TraceBench>,
    /// Autopilot search throughput, exhaustive vs racing (None when the
    /// section was not run).
    pub optimize: Option<OptimizeBench>,
}

/// One replay; the timer starts AFTER `Network::new` so construction
/// cost (route-table tabulation, arena zeroing) never skews the
/// per-flit throughput this file exists to track.
fn run_once(pt: &BenchPoint, engine: SimEngine, trace: &Trace) -> (f64, u64, NetStats) {
    let cfg = NocConfig { engine, ..NocConfig::paper() };
    let mut net = Network::new(&pt.topo, cfg);
    let t = Instant::now();
    let cycles = scenario::replay(&mut net, trace, 100_000_000)
        .unwrap_or_else(|e| panic!("{}: {e}", pt.label));
    let wall_s = t.elapsed().as_secs_f64();
    (wall_s, cycles, net.stats().clone())
}

/// Best-of-`reps` wall time plus the run digest (identical across reps:
/// the simulator is deterministic).
fn time_cell(
    pt: &BenchPoint,
    engine: SimEngine,
    trace: &Trace,
    reps: usize,
) -> (CellResult, (u64, NetStats)) {
    let mut best = f64::INFINITY;
    let mut digest = None;
    for _ in 0..reps {
        let (wall_s, cycles, stats) = run_once(pt, engine, trace);
        best = best.min(wall_s);
        digest = Some((cycles, stats));
    }
    let (cycles, stats) = digest.unwrap();
    assert_eq!(
        stats.injected, stats.delivered,
        "{}: lost flits under {engine:?}",
        pt.label
    );
    let cell = CellResult { engine, wall_s: best, flits: stats.delivered, cycles };
    (cell, (cycles, stats))
}

/// Run one point on both engines, asserting bit-identity of the digests
/// produced by the timed runs themselves (no extra untimed replay).
pub fn run_point(pt: &BenchPoint, reps: usize, window_scale: f64) -> PointResult {
    let scn = scenario::find(pt.scenario).expect("scenario registered");
    let n = pt.topo.build().n_endpoints;
    let window = ((pt.window as f64 * window_scale) as u64).max(100);
    let trace = scn.trace(n, pt.load, window, 1);
    let (reference, ref_digest) = time_cell(pt, SimEngine::Reference, &trace, reps);
    let (event, evt_digest) = time_cell(pt, SimEngine::EventDriven, &trace, reps);
    assert_eq!(
        ref_digest, evt_digest,
        "{}: engines disagree — conformance bug, numbers would be meaningless",
        pt.label
    );
    PointResult { label: pt.label, reference, event }
}

/// Run one monolithic-vs-sharded point (event engine on both sides;
/// the engines' own conformance is covered by [`run_point`]).
pub fn run_multichip_point(pt: &MultiBenchPoint, reps: usize, window_scale: f64) -> MultiPointResult {
    let scn = scenario::find(pt.scenario).expect("scenario registered");
    let graph = pt.topo.build();
    let n = graph.n_endpoints;
    let window = ((pt.window as f64 * window_scale) as u64).max(100);
    let trace = scn.trace(n, pt.load, window, 1);
    let cfg = NocConfig { engine: SimEngine::EventDriven, ..NocConfig::paper() };
    let partition = Partition::balanced(&graph, pt.n_fpgas, 1);
    let serdes = SerdesConfig { pins: pt.pins, clock_div: pt.clock_div, tx_buffer: 8 };

    let mut mono_best = f64::INFINITY;
    let mut mono_digest = (0u64, NetStats::default());
    for _ in 0..reps {
        let mut net = Network::new(&pt.topo, cfg);
        let t = Instant::now();
        let cycles = scenario::replay(&mut net, &trace, 100_000_000)
            .unwrap_or_else(|e| panic!("{} (mono): {e}", pt.label));
        mono_best = mono_best.min(t.elapsed().as_secs_f64());
        mono_digest = (cycles, net.stats().clone());
    }
    let mut sh_best = f64::INFINITY;
    let mut sh_digest = (0u64, NetStats::default());
    for _ in 0..reps {
        let mut sim = MultiChipSim::from_graph(graph.clone(), cfg, &partition, serdes);
        let t = Instant::now();
        let cycles = scenario::replay_multichip(&mut sim, &trace, 1_000_000_000)
            .unwrap_or_else(|e| panic!("{} (sharded): {e}", pt.label));
        sh_best = sh_best.min(t.elapsed().as_secs_f64());
        sh_digest = (cycles, sim.stats());
    }
    // Conformance: neither side lost flits, the shards followed the
    // monolithic routes (hop counts match), and serialization only adds.
    assert_eq!(mono_digest.1.injected, mono_digest.1.delivered, "{}", pt.label);
    assert_eq!(sh_digest.1.injected, sh_digest.1.delivered, "{}", pt.label);
    assert_eq!(mono_digest.1.delivered, sh_digest.1.delivered, "{}", pt.label);
    assert_eq!(mono_digest.1.link_hops, sh_digest.1.link_hops, "{}", pt.label);
    assert!(sh_digest.0 >= mono_digest.0, "{}: serdes made it faster?!", pt.label);
    MultiPointResult {
        label: pt.label,
        mono: CellResult {
            engine: SimEngine::EventDriven,
            wall_s: mono_best,
            flits: mono_digest.1.delivered,
            cycles: mono_digest.0,
        },
        sharded: CellResult {
            engine: SimEngine::EventDriven,
            wall_s: sh_best,
            flits: sh_digest.1.delivered,
            cycles: sh_digest.0,
        },
    }
}

/// Run the fleet sweep benchmark (the `"sweep"` section): one grid
/// timed at 1 worker and at N, results asserted bit-identical, plus the
/// construct-once-vs-rebuild comparison on a full-route-cube torus.
pub fn run_sweep_bench(quick: bool) -> SweepBench {
    let seeds: Vec<u64> = if quick { (1..=6).collect() } else { (1..=16).collect() };
    let grid = SweepGrid {
        topo: Topology::Mesh { w: 8, h: 8 },
        cfg: NocConfig { engine: SimEngine::EventDriven, ..NocConfig::paper() },
        scenarios: ["uniform", "hotspot", "bursty"]
            .iter()
            .map(|n| scenario::find(n).expect("scenario registered"))
            .collect(),
        loads: vec![0.02, 0.1],
        seeds,
        cycles: if quick { 400 } else { 1200 },
        lanes: 1,
    };
    let grid_jobs = grid.jobs().len();
    let threads = fleet::default_threads().max(2);
    let t = Instant::now();
    let serial = scenario::run_grid(&grid, 1).expect("sweep grid stalled (serial)");
    let serial_s = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let parallel = scenario::run_grid(&grid, threads).expect("sweep grid stalled (parallel)");
    let parallel_s = t.elapsed().as_secs_f64();
    assert_eq!(
        serial, parallel,
        "fleet output must be thread-count invariant — numbers would be meaningless"
    );

    // Construct-once vs rebuild: a torus tabulates the full
    // [router][src][dst] route cube, so per-job reconstruction is the
    // dominant cost at a short window — exactly the overhead
    // SharedFabric + reset() deletes. Both loops run the identical job
    // list and must deliver identical flit totals.
    let topo = Topology::Torus { w: 8, h: 8 };
    let cfg = NocConfig { engine: SimEngine::EventDriven, ..NocConfig::paper() };
    let scn = scenario::find("uniform").expect("scenario registered");
    let reuse_jobs = if quick { 16 } else { 48 };
    let window = 200u64;
    let mut rebuilt_flits = 0u64;
    let t = Instant::now();
    for s in 0..reuse_jobs {
        let mut net = Network::new(&topo, cfg);
        let trace = scn.trace(net.n_endpoints(), 0.05, window, s as u64 + 1);
        scenario::replay(&mut net, &trace, 10_000_000).expect("rebuild job stalled");
        rebuilt_flits += net.stats().delivered;
    }
    let rebuild_s = t.elapsed().as_secs_f64();
    let mut reused_flits = 0u64;
    let t = Instant::now();
    let fabric = SharedFabric::new(&topo);
    let mut net = fabric.network(cfg);
    for s in 0..reuse_jobs {
        net.reset();
        let trace = scn.trace(net.n_endpoints(), 0.05, window, s as u64 + 1);
        scenario::replay(&mut net, &trace, 10_000_000).expect("reuse job stalled");
        reused_flits += net.stats().delivered;
    }
    let reuse_s = t.elapsed().as_secs_f64();
    assert_eq!(rebuilt_flits, reused_flits, "reset() run diverged from rebuilds");
    SweepBench {
        grid_jobs,
        threads,
        serial_jobs_per_sec: grid_jobs as f64 / serial_s,
        parallel_jobs_per_sec: grid_jobs as f64 / parallel_s,
        parallel_speedup: serial_s / parallel_s,
        reuse_jobs,
        rebuild_jobs_per_sec: reuse_jobs as f64 / rebuild_s,
        reuse_jobs_per_sec: reuse_jobs as f64 / reuse_s,
        reuse_speedup: rebuild_s / reuse_s,
    }
}

/// Run the serving benchmark (the `"serve"` section): the same seeded
/// scenario-request stream offered at increasing Poisson rates through
/// an in-process [`loadgen::PacedReader`] → [`serve::serve_stream`]
/// pipe, plus one unpaced flood point that drives the pool into
/// admission control. Reject admission with the default bounded queue:
/// below saturation every paced point must serve everything; the flood
/// point is where rejection shows up.
pub fn run_serve_bench(quick: bool) -> ServeBench {
    let cfg = serve::ServeConfig {
        admission: serve::Admission::Reject,
        ..serve::ServeConfig::default()
    };
    let requests: u64 = if quick { 60 } else { 300 };
    let rates: &[f64] = if quick { &[500.0, 2000.0] } else { &[500.0, 2000.0, 8000.0] };
    let mut points = Vec::new();
    for (i, &rate) in rates.iter().chain(std::iter::once(&0.0)).enumerate() {
        let lg = loadgen::LoadgenConfig {
            requests,
            rate,
            seed: 7,
            mix: vec![loadgen::ReqKind::Scenario],
            arrivals: loadgen::ArrivalModel::Poisson,
            bmvm: cfg.bmvm.clone(),
        };
        let label = if rate > 0.0 {
            format!("poisson-{}rps", rate as u64)
        } else {
            "flood".to_string()
        };
        let input = loadgen::PacedReader::new(&lg);
        // Responses go to a discarding sink: their bytes are covered by
        // the differential tests; the bench only tracks timing.
        let summary = serve::serve_stream(&cfg, input, std::io::sink())
            .unwrap_or_else(|e| panic!("serve bench point {i}: {e}"));
        assert_eq!(
            summary.arrived, requests,
            "{label}: loadgen stream lost frames in flight"
        );
        assert_eq!(summary.errors, 0, "{label}: loadgen emitted an unservable request");
        points.push(ServePoint {
            label,
            offered_rps: rate,
            requests,
            served: summary.served,
            rejected: summary.rejected,
            achieved_rps: summary.achieved_rps(),
            p50_us: summary.latency_us.p50(),
            p95_us: summary.latency_us.p95(),
            p99_us: summary.latency_us.p99(),
            max_us: summary.latency_us.max_latency,
            rejection_rate: summary.rejection_rate(),
        });
    }
    ServeBench { threads: cfg.threads, queue_cap: cfg.queue_cap, points }
}

/// Run the wire-fault benchmark (the `"faults"` section): the same
/// uniform trace replayed on a 2-way bisected mesh at increasing seeded
/// fault rates with CRC/retransmit protection on. Every rate must
/// deliver exactly the clean flit count (asserted here — survival, not
/// best-effort); what the section tracks is the *cost*: completion-cycle
/// overhead vs the clean run and goodput in delivered flits per cycle.
/// Nonzero rates also pay the CRC field's serialization stretch, so
/// overhead is protection + recovery, which is what a deployment pays.
pub fn run_faults_bench(quick: bool) -> FaultsBench {
    const RATES: [f64; 4] = [0.0, 1e-4, 1e-3, 1e-2];
    let topo = Topology::Mesh { w: 4, h: 4 };
    let graph = topo.build();
    let scn = scenario::find("uniform").expect("scenario registered");
    let window = if quick { 500 } else { 2_000 };
    let trace = scn.trace(graph.n_endpoints, 0.1, window, 1);
    let cfg = NocConfig { engine: SimEngine::EventDriven, ..NocConfig::paper() };
    let partition = Partition::balanced(&graph, 2, 1);
    let serdes = SerdesConfig { pins: 8, clock_div: 1, tx_buffer: 8 };

    let mut points: Vec<FaultPoint> = Vec::new();
    for &rate in &RATES {
        let mut sim = MultiChipSim::from_graph(graph.clone(), cfg, &partition, serdes);
        let plan = if rate > 0.0 {
            FaultPlan::new(0xFA17_BE4C ^ rate.to_bits()).flips(rate).drops(rate)
        } else {
            FaultPlan::new(0)
        };
        sim.set_fault_plan(&plan);
        let cycles = scenario::replay_multichip(&mut sim, &trace, 1_000_000_000)
            .unwrap_or_else(|e| panic!("faults bench @{rate}: {e}"));
        let stats = sim.stats();
        assert_eq!(stats.injected, stats.delivered, "faults bench lost flits @{rate}");
        let (mut retransmits, mut corrupted) = (0u64, 0u64);
        for l in sim.link_stats() {
            retransmits += l.retransmitted;
            corrupted += l.corrupted;
        }
        let clean_cycles = points.first().map_or(cycles, |p: &FaultPoint| p.cycles);
        assert_eq!(
            points.first().map_or(stats.delivered, |p| p.delivered),
            stats.delivered,
            "fault rate {rate} changed the delivered flit count — exactly-once broken"
        );
        points.push(FaultPoint {
            rate,
            cycles,
            delivered: stats.delivered,
            retransmits,
            corrupted,
            goodput: stats.delivered as f64 / cycles as f64,
            overhead: cycles as f64 / clean_cycles as f64,
        });
    }
    FaultsBench { scenario: "uniform", pins: serdes.pins, clock_div: serdes.clock_div, points }
}

/// Run the bitsliced Monte-Carlo benchmark (the `"bitsliced"` section):
/// one LDPC BER point decoded for the same lane seeds by the scalar
/// reference loop and by the 64-lane bitsliced decoder, at 1, 8 and 64
/// lanes. Per-lane results are asserted bit-identical in the same run —
/// the throughput column never trades correctness — and at 64 lanes the
/// sliced path must beat (or at worst match) the scalar loop.
pub fn run_bitsliced_bench(quick: bool) -> BitslicedBench {
    use crate::apps::ldpc::{ber, MinsumVariant, ReferenceDecoder, SlicedDecoder};
    use crate::gf2::pg::PgLdpcCode;
    // PG(2, 4): N = 21, degree 5 — large enough that a decode dominates
    // the RNG draws, small enough for the quick profile.
    let code = PgLdpcCode::new(2);
    let variant = MinsumVariant::SignMagnitude;
    let frames = if quick { 150 } else { 1_500 };
    let niter = 8u32;
    let (p, amp) = (0.04, 8_000);
    let scalar_dec = ReferenceDecoder::new(code.clone(), variant);
    let mut sliced_dec = SlicedDecoder::new(code, variant);
    let mut points = Vec::new();
    for lanes in [1usize, 8, 64] {
        let seeds = ber::lane_seeds(0xB175_11CE, lanes);
        let t = Instant::now();
        let scalar: Vec<_> = seeds
            .iter()
            .map(|&s| ber::ber_point(&scalar_dec, p, frames, niter, amp, s))
            .collect();
        let scalar_s = t.elapsed().as_secs_f64();
        let t = Instant::now();
        let sliced = ber::ber_point_sliced(&mut sliced_dec, p, frames, niter, amp, &seeds);
        let sliced_s = t.elapsed().as_secs_f64();
        assert_eq!(
            scalar, sliced,
            "bitsliced lanes diverged from the scalar loop at {lanes} lanes — \
             the throughput numbers would be meaningless"
        );
        let point = BitslicedPoint {
            lanes,
            scalar_seeds_per_sec: lanes as f64 / scalar_s,
            sliced_seeds_per_sec: lanes as f64 / sliced_s,
            speedup: scalar_s / sliced_s,
        };
        if lanes == 64 {
            assert!(
                point.sliced_seeds_per_sec >= point.scalar_seeds_per_sec,
                "bitsliced decode lost to the scalar loop at 64 lanes \
                 ({:.0} vs {:.0} seeds/sec)",
                point.sliced_seeds_per_sec,
                point.scalar_seeds_per_sec
            );
        }
        points.push(point);
    }
    BitslicedBench { code: "pg(2,4)", variant: "sign-magnitude", frames, niter, points }
}

/// Run the tracing benchmark (the `"trace"` section). Overhead half:
/// the hotspot scenario replayed with the recorder off and on — the run
/// digests must be bit-identical (tracing observes, never steers), so
/// the only difference the section reports is wall clock. Placement
/// half: the measure → re-place loop on a 2-chip flow whose declared
/// channel weights hide a hotspot — the static placer's deterministic
/// tie-break exiles the hot stream across the serializing wire, a traced
/// run measures the real loads, and `profile_guided` must strictly cut
/// completion cycles. Both assertions run here, in the same process that
/// produces the numbers.
pub fn run_trace_bench(quick: bool) -> TraceBench {
    use crate::flow::{FlowBuilder, MappedFlow};
    use crate::noc::ChannelProfile;
    use crate::pe::collector::ArgMessage;
    use crate::pe::{MsgSink, OutMessage, Processor, WrapperSpec};

    // --- recorder overhead: hotspot replay, recorder off vs on -------
    let topo = Topology::Mesh { w: 8, h: 8 };
    let scn = scenario::find("hotspot").expect("scenario registered");
    let n = topo.build().n_endpoints;
    let window = if quick { 1_000 } else { 5_000 };
    let trace = scn.trace(n, 0.1, window, 1);
    let cfg = NocConfig { engine: SimEngine::EventDriven, ..NocConfig::paper() };
    let reps = if quick { 1 } else { 3 };

    let mut untraced_best = f64::INFINITY;
    let mut untraced_digest = (0u64, NetStats::default());
    for _ in 0..reps {
        let mut net = Network::new(&topo, cfg);
        let t = Instant::now();
        let cycles = scenario::replay(&mut net, &trace, 100_000_000)
            .expect("trace bench (untraced) stalled");
        untraced_best = untraced_best.min(t.elapsed().as_secs_f64());
        untraced_digest = (cycles, net.stats().clone());
    }
    let mut traced_best = f64::INFINITY;
    let mut traced_digest = (0u64, NetStats::default());
    let mut events = 0u64;
    for _ in 0..reps {
        let mut net = Network::new(&topo, cfg);
        net.enable_trace(1 << 15);
        let t = Instant::now();
        let cycles = scenario::replay(&mut net, &trace, 100_000_000)
            .expect("trace bench (traced) stalled");
        traced_best = traced_best.min(t.elapsed().as_secs_f64());
        traced_digest = (cycles, net.stats().clone());
        events = net.trace().expect("recorder enabled").recorded();
    }
    assert_eq!(
        untraced_digest, traced_digest,
        "tracing changed the simulation — it must observe, never steer"
    );
    assert!(events > 0, "traced hotspot replay recorded nothing");

    // --- profile-guided placement win on a 2-chip hotspot flow -------
    /// Boot-time source sending fixed messages, then idle.
    struct BootSource {
        msgs: Vec<OutMessage>,
    }
    impl Processor for BootSource {
        fn spec(&self) -> WrapperSpec {
            WrapperSpec::new(vec![8], vec![16])
        }
        fn boot(&mut self, out: &mut MsgSink) {
            for m in std::mem::take(&mut self.msgs) {
                out.push(m);
            }
        }
        fn process(&mut self, _: &[ArgMessage], _: u32, _: &mut MsgSink) {}
    }
    let hot_msgs: u32 = if quick { 24 } else { 64 };
    let build = |measured: Option<ChannelProfile>,
                 targets: Option<(usize, usize)>|
     -> MappedFlow {
        let msgs = match targets {
            None => Vec::new(),
            Some((hot_ep, cold_ep)) => {
                let mut m = vec![OutMessage::word(cold_ep, 0, 0, 7, 16)];
                m.extend(
                    (0..hot_msgs).map(|e| OutMessage::word(hot_ep, 0, e, e as u64, 16)),
                );
                m
            }
        };
        let mut fb = FlowBuilder::new("trace-bench");
        fb.topology(Topology::Mesh { w: 2, h: 2 })
            .pe_at("src", 0, Box::new(BootSource { msgs }))
            .tap("cold")
            .tap("hot")
            .channel("src", "cold")
            .channel("src", "hot")
            .partition(Partition::new(2, vec![0, 0, 1, 1]))
            .multichip(SerdesConfig::default());
        if let Some(p) = measured {
            fb.profile_guided(p);
        }
        fb.build().expect("trace bench flow build")
    };
    // Placement is independent of the boot messages: probe builds reveal
    // where the taps land before wiring the sources at those endpoints.
    let probe = build(None, None);
    let static_eps = (probe.node_of("hot").unwrap(), probe.node_of("cold").unwrap());
    let mut static_flow = build(None, Some(static_eps));
    static_flow.enable_trace(1 << 12);
    let static_report = static_flow.run().expect("trace bench static flow");
    let profile = static_flow.unit_channel_profile();
    let guided_probe = build(Some(profile.clone()), None);
    let guided_eps = (
        guided_probe.node_of("hot").unwrap(),
        guided_probe.node_of("cold").unwrap(),
    );
    let mut guided_flow = build(Some(profile), Some(guided_eps));
    let guided_report = guided_flow.run().expect("trace bench guided flow");
    assert!(
        guided_report.cycles < static_report.cycles,
        "profile-guided placement must strictly beat static: {} !< {}",
        guided_report.cycles,
        static_report.cycles
    );

    TraceBench {
        scenario: "hotspot",
        cycles: untraced_digest.0,
        events,
        untraced_wall_ms: untraced_best * 1e3,
        traced_wall_ms: traced_best * 1e3,
        trace_overhead: traced_best / untraced_best,
        static_cycles: static_report.cycles,
        guided_cycles: guided_report.cycles,
        guided_speedup: static_report.cycles as f64 / guided_report.cycles as f64,
    }
}

/// Run the whole tracked matrix. `quick` shrinks windows 4x and uses one
/// rep — the CI perf-smoke profile.
pub fn run(quick: bool) -> BenchReport {
    run_selected(quick, BenchSelect::ALL)
}

/// Run only the selected sections (`fabricflow bench --only …`). The
/// point matrices are enumerated through the fleet pool at ONE worker:
/// cells time wall-clock, so running them concurrently would contend
/// and corrupt the numbers — the fleet here buys the job/slot plumbing,
/// not parallelism. The sweep section is where threads>1 is measured.
pub fn run_selected(quick: bool, sel: BenchSelect) -> BenchReport {
    let (reps, scale) = if quick { (1, 0.25) } else { (3, 1.0) };
    let points = if sel.points {
        let pts = points();
        fleet::run_jobs(&pts, 1, |_| (), |_, pt, _| run_point(pt, reps, scale))
    } else {
        Vec::new()
    };
    let multichip = if sel.multichip {
        let pts = multichip_points();
        fleet::run_jobs(&pts, 1, |_| (), |_, pt, _| run_multichip_point(pt, reps, scale))
    } else {
        Vec::new()
    };
    let sweep = sel.sweep.then(|| run_sweep_bench(quick));
    let serve = sel.serve.then(|| run_serve_bench(quick));
    let faults = sel.faults.then(|| run_faults_bench(quick));
    let bitsliced = sel.bitsliced.then(|| run_bitsliced_bench(quick));
    let trace = sel.trace.then(|| run_trace_bench(quick));
    let optimize = sel.optimize.then(|| run_optimize_bench(quick));
    BenchReport { quick, points, multichip, sweep, serve, faults, bitsliced, trace, optimize }
}

impl BenchReport {
    /// Serialize as stable, diffable JSON (hand-rolled: the default
    /// build has no dependencies).
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut j = String::new();
        let _ = writeln!(j, "{{");
        let _ = writeln!(j, "  \"schema\": \"fabricflow-bench-noc/v1\",");
        let _ = writeln!(j, "  \"profile\": \"{}\",", if self.quick { "quick" } else { "full" });
        let _ = writeln!(
            j,
            "  \"note\": \"regenerate with `cargo run --release -- bench{}`\",",
            if self.quick { " --quick" } else { "" }
        );
        let _ = writeln!(j, "  \"points\": [");
        for (i, p) in self.points.iter().enumerate() {
            let comma = if i + 1 == self.points.len() { "" } else { "," };
            let _ = writeln!(j, "    {{");
            let _ = writeln!(j, "      \"label\": \"{}\",", p.label);
            for (key, c) in [("reference", &p.reference), ("event", &p.event)] {
                let _ = writeln!(j, "      \"{key}\": {{");
                let _ = writeln!(j, "        \"flits\": {},", c.flits);
                let _ = writeln!(j, "        \"cycles\": {},", c.cycles);
                let _ = writeln!(j, "        \"wall_ms\": {:.3},", c.wall_s * 1e3);
                let _ = writeln!(j, "        \"flits_per_sec\": {:.0},", c.flits_per_sec());
                let _ = writeln!(j, "        \"cycles_per_sec\": {:.0}", c.cycles_per_sec());
                let _ = writeln!(j, "      }},");
            }
            let _ = writeln!(j, "      \"event_speedup\": {:.2}", p.speedup());
            let _ = writeln!(j, "    }}{comma}");
        }
        let _ = writeln!(j, "  ],");
        let _ = writeln!(j, "  \"multichip\": [");
        for (i, p) in self.multichip.iter().enumerate() {
            let comma = if i + 1 == self.multichip.len() { "" } else { "," };
            let _ = writeln!(j, "    {{");
            let _ = writeln!(j, "      \"label\": \"{}\",", p.label);
            for (key, c) in [("monolithic", &p.mono), ("sharded", &p.sharded)] {
                let _ = writeln!(j, "      \"{key}\": {{");
                let _ = writeln!(j, "        \"flits\": {},", c.flits);
                let _ = writeln!(j, "        \"cycles\": {},", c.cycles);
                let _ = writeln!(j, "        \"wall_ms\": {:.3},", c.wall_s * 1e3);
                let _ = writeln!(j, "        \"flits_per_sec\": {:.0}", c.flits_per_sec());
                let _ = writeln!(j, "      }},");
            }
            let _ = writeln!(j, "      \"cycle_slowdown\": {:.3},", p.cycle_slowdown());
            let _ = writeln!(j, "      \"wall_ratio\": {:.2}", p.wall_ratio());
            let _ = writeln!(j, "    }}{comma}");
        }
        let _ = writeln!(j, "  ],");
        match &self.sweep {
            Some(s) => {
                let _ = writeln!(j, "  \"sweep\": {{");
                let _ = writeln!(j, "    \"grid_jobs\": {},", s.grid_jobs);
                let _ = writeln!(j, "    \"threads\": {},", s.threads);
                let _ = writeln!(j, "    \"serial_jobs_per_sec\": {:.1},", s.serial_jobs_per_sec);
                let _ = writeln!(
                    j,
                    "    \"parallel_jobs_per_sec\": {:.1},",
                    s.parallel_jobs_per_sec
                );
                let _ = writeln!(j, "    \"parallel_speedup\": {:.2},", s.parallel_speedup);
                let _ = writeln!(j, "    \"reuse_jobs\": {},", s.reuse_jobs);
                let _ = writeln!(
                    j,
                    "    \"rebuild_jobs_per_sec\": {:.1},",
                    s.rebuild_jobs_per_sec
                );
                let _ = writeln!(j, "    \"reuse_jobs_per_sec\": {:.1},", s.reuse_jobs_per_sec);
                let _ = writeln!(j, "    \"reuse_speedup\": {:.2}", s.reuse_speedup);
                let _ = writeln!(j, "  }},");
            }
            None => {
                let _ = writeln!(j, "  \"sweep\": null,");
            }
        }
        match &self.serve {
            Some(sv) => {
                let _ = writeln!(j, "  \"serve\": {{");
                let _ = writeln!(j, "    \"threads\": {},", sv.threads);
                let _ = writeln!(j, "    \"queue_cap\": {},", sv.queue_cap);
                let _ = writeln!(j, "    \"points\": [");
                for (i, p) in sv.points.iter().enumerate() {
                    let comma = if i + 1 == sv.points.len() { "" } else { "," };
                    let _ = writeln!(j, "      {{");
                    let _ = writeln!(j, "        \"label\": \"{}\",", p.label);
                    let _ = writeln!(j, "        \"offered_rps\": {:.1},", p.offered_rps);
                    let _ = writeln!(j, "        \"requests\": {},", p.requests);
                    let _ = writeln!(j, "        \"served\": {},", p.served);
                    let _ = writeln!(j, "        \"rejected\": {},", p.rejected);
                    let _ = writeln!(j, "        \"achieved_rps\": {:.1},", p.achieved_rps);
                    let _ = writeln!(j, "        \"p50_us\": {},", p.p50_us);
                    let _ = writeln!(j, "        \"p95_us\": {},", p.p95_us);
                    let _ = writeln!(j, "        \"p99_us\": {},", p.p99_us);
                    let _ = writeln!(j, "        \"max_us\": {},", p.max_us);
                    let _ = writeln!(j, "        \"rejection_rate\": {:.4}", p.rejection_rate);
                    let _ = writeln!(j, "      }}{comma}");
                }
                let _ = writeln!(j, "    ]");
                let _ = writeln!(j, "  }},");
            }
            None => {
                let _ = writeln!(j, "  \"serve\": null,");
            }
        }
        match &self.faults {
            Some(fb) => {
                let _ = writeln!(j, "  \"faults\": {{");
                let _ = writeln!(j, "    \"scenario\": \"{}\",", fb.scenario);
                let _ = writeln!(j, "    \"pins\": {},", fb.pins);
                let _ = writeln!(j, "    \"clock_div\": {},", fb.clock_div);
                let _ = writeln!(j, "    \"points\": [");
                for (i, p) in fb.points.iter().enumerate() {
                    let comma = if i + 1 == fb.points.len() { "" } else { "," };
                    let _ = writeln!(j, "      {{");
                    let _ = writeln!(j, "        \"rate\": {},", p.rate);
                    let _ = writeln!(j, "        \"cycles\": {},", p.cycles);
                    let _ = writeln!(j, "        \"delivered\": {},", p.delivered);
                    let _ = writeln!(j, "        \"retransmits\": {},", p.retransmits);
                    let _ = writeln!(j, "        \"corrupted\": {},", p.corrupted);
                    let _ = writeln!(j, "        \"goodput\": {:.4},", p.goodput);
                    let _ = writeln!(j, "        \"overhead\": {:.3}", p.overhead);
                    let _ = writeln!(j, "      }}{comma}");
                }
                let _ = writeln!(j, "    ]");
                let _ = writeln!(j, "  }},");
            }
            None => {
                let _ = writeln!(j, "  \"faults\": null,");
            }
        }
        match &self.bitsliced {
            Some(bs) => {
                let _ = writeln!(j, "  \"bitsliced\": {{");
                let _ = writeln!(j, "    \"code\": \"{}\",", bs.code);
                let _ = writeln!(j, "    \"variant\": \"{}\",", bs.variant);
                let _ = writeln!(j, "    \"frames\": {},", bs.frames);
                let _ = writeln!(j, "    \"niter\": {},", bs.niter);
                let _ = writeln!(j, "    \"points\": [");
                for (i, p) in bs.points.iter().enumerate() {
                    let comma = if i + 1 == bs.points.len() { "" } else { "," };
                    let _ = writeln!(j, "      {{");
                    let _ = writeln!(j, "        \"lanes\": {},", p.lanes);
                    let _ = writeln!(
                        j,
                        "        \"scalar_seeds_per_sec\": {:.1},",
                        p.scalar_seeds_per_sec
                    );
                    let _ = writeln!(
                        j,
                        "        \"sliced_seeds_per_sec\": {:.1},",
                        p.sliced_seeds_per_sec
                    );
                    let _ = writeln!(j, "        \"speedup\": {:.2}", p.speedup);
                    let _ = writeln!(j, "      }}{comma}");
                }
                let _ = writeln!(j, "    ]");
                let _ = writeln!(j, "  }},");
            }
            None => {
                let _ = writeln!(j, "  \"bitsliced\": null,");
            }
        }
        match &self.trace {
            Some(tr) => {
                let _ = writeln!(j, "  \"trace\": {{");
                let _ = writeln!(j, "    \"scenario\": \"{}\",", tr.scenario);
                let _ = writeln!(j, "    \"cycles\": {},", tr.cycles);
                let _ = writeln!(j, "    \"events\": {},", tr.events);
                let _ = writeln!(j, "    \"untraced_wall_ms\": {:.3},", tr.untraced_wall_ms);
                let _ = writeln!(j, "    \"traced_wall_ms\": {:.3},", tr.traced_wall_ms);
                let _ = writeln!(j, "    \"trace_overhead\": {:.2},", tr.trace_overhead);
                let _ = writeln!(j, "    \"static_cycles\": {},", tr.static_cycles);
                let _ = writeln!(j, "    \"guided_cycles\": {},", tr.guided_cycles);
                let _ = writeln!(j, "    \"guided_speedup\": {:.2}", tr.guided_speedup);
                let _ = writeln!(j, "  }},");
            }
            None => {
                let _ = writeln!(j, "  \"trace\": null,");
            }
        }
        match &self.optimize {
            Some(op) => {
                let _ = writeln!(j, "  \"optimize\": {{");
                let _ = writeln!(j, "    \"scenario\": \"{}\",", op.scenario);
                let _ = writeln!(j, "    \"space_points\": {},", op.space_points);
                let _ = writeln!(j, "    \"threads\": {},", op.threads);
                let _ = writeln!(j, "    \"front_size\": {},", op.front_size);
                let _ = writeln!(
                    j,
                    "    \"exhaustive_full_runs\": {},",
                    op.exhaustive_full_runs
                );
                let _ = writeln!(j, "    \"racing_full_runs\": {},", op.racing_full_runs);
                let _ = writeln!(j, "    \"racing_probe_runs\": {},", op.racing_probe_runs);
                let _ = writeln!(j, "    \"racing_pruned\": {},", op.racing_pruned);
                let _ = writeln!(
                    j,
                    "    \"sequential_evals_per_sec\": {:.1},",
                    op.sequential_evals_per_sec
                );
                let _ = writeln!(
                    j,
                    "    \"racing_evals_per_sec\": {:.1},",
                    op.racing_evals_per_sec
                );
                let _ = writeln!(j, "    \"racing_speedup\": {:.2}", op.racing_speedup);
                let _ = writeln!(j, "  }}");
            }
            None => {
                let _ = writeln!(j, "  \"optimize\": null");
            }
        }
        let _ = writeln!(j, "}}");
        j
    }

    /// Human-readable table (the CLI and bench-binary printout).
    pub fn render_table(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        let _ = writeln!(
            s,
            "NoC benchmark matrix ({} profile; bit-identity asserted per point)",
            if self.quick { "quick" } else { "full" }
        );
        for p in &self.points {
            let _ = writeln!(
                s,
                "  {:32} {:>8} flits {:>9} cyc | ref {:>9.0} flit/s  event {:>9.0} flit/s  => {:.2}x",
                p.label,
                p.reference.flits,
                p.reference.cycles,
                p.reference.flits_per_sec(),
                p.event.flits_per_sec(),
                p.speedup()
            );
        }
        if !self.multichip.is_empty() {
            let _ = writeln!(s, "Monolithic vs sharded multi-FPGA (simulated-cycle slowdown)");
            for p in &self.multichip {
                let _ = writeln!(
                    s,
                    "  {:32} {:>8} flits | mono {:>9} cyc  sharded {:>9} cyc  => {:.2}x slower",
                    p.label,
                    p.mono.flits,
                    p.mono.cycles,
                    p.sharded.cycles,
                    p.cycle_slowdown()
                );
            }
        }
        if let Some(sw) = &self.sweep {
            let _ = writeln!(s, "Fleet sweep throughput (results asserted thread-invariant)");
            let _ = writeln!(
                s,
                "  {:32} {:>8.1} job/s @1T {:>8.1} job/s @{}T  => {:.2}x",
                format!("grid/{} jobs", sw.grid_jobs),
                sw.serial_jobs_per_sec,
                sw.parallel_jobs_per_sec,
                sw.threads,
                sw.parallel_speedup
            );
            let _ = writeln!(
                s,
                "  {:32} {:>8.1} job/s fresh {:>6.1} job/s reset  => {:.2}x",
                format!("construct-once/{} jobs", sw.reuse_jobs),
                sw.rebuild_jobs_per_sec,
                sw.reuse_jobs_per_sec,
                sw.reuse_speedup
            );
        }
        if let Some(sv) = &self.serve {
            let _ = writeln!(
                s,
                "Serving latency vs offered load ({} threads, queue {})",
                sv.threads, sv.queue_cap
            );
            for p in &sv.points {
                let _ = writeln!(
                    s,
                    "  {:32} {:>8.0} req/s offered {:>8.0} served | p50 {:>6}us p99 {:>6}us | rej {:>5.1}%",
                    p.label,
                    p.offered_rps,
                    p.achieved_rps,
                    p.p50_us,
                    p.p99_us,
                    p.rejection_rate * 100.0
                );
            }
        }
        if let Some(fb) = &self.faults {
            let _ = writeln!(
                s,
                "Wire-fault recovery cost ({} on bisected mesh4x4, {} pins; every rate delivers everything)",
                fb.scenario, fb.pins
            );
            for p in &fb.points {
                let _ = writeln!(
                    s,
                    "  rate {:<10} {:>9} cyc ({:.3}x clean) | {:>6} retrans {:>6} corrupt | goodput {:.4} flit/cyc",
                    p.rate, p.cycles, p.overhead, p.retransmits, p.corrupted, p.goodput
                );
            }
        }
        if let Some(bs) = &self.bitsliced {
            let _ = writeln!(
                s,
                "Bitsliced Monte-Carlo ({} {} minsum, {} frames x {} iters; lanes asserted bit-identical)",
                bs.code, bs.variant, bs.frames, bs.niter
            );
            for p in &bs.points {
                let _ = writeln!(
                    s,
                    "  {:>3} lanes {:>9.1} seeds/s scalar {:>9.1} seeds/s sliced  => {:.2}x",
                    p.lanes, p.scalar_seeds_per_sec, p.sliced_seeds_per_sec, p.speedup
                );
            }
        }
        if let Some(tr) = &self.trace {
            let _ = writeln!(
                s,
                "Trace recorder ({}; run digest asserted identical traced and untraced)",
                tr.scenario
            );
            let _ = writeln!(
                s,
                "  overhead  {:>9.1} ms untraced {:>9.1} ms traced  => {:.2}x ({} events)",
                tr.untraced_wall_ms, tr.traced_wall_ms, tr.trace_overhead, tr.events
            );
            let _ = writeln!(
                s,
                "  profile-guided placement  {:>9} cyc static {:>9} cyc guided  => {:.2}x",
                tr.static_cycles, tr.guided_cycles, tr.guided_speedup
            );
        }
        if let Some(op) = &self.optimize {
            let _ = writeln!(
                s,
                "Design-space autopilot ({}, {} points; racing front asserted identical to exhaustive)",
                op.scenario, op.space_points
            );
            let _ = writeln!(
                s,
                "  {:>9.1} pts/s exhaustive@1T {:>9.1} pts/s racing@{}T  => {:.2}x",
                op.sequential_evals_per_sec,
                op.racing_evals_per_sec,
                op.threads,
                op.racing_speedup
            );
            let _ = writeln!(
                s,
                "  full runs {} -> {} ({} probes, {} pruned), front {}",
                op.exhaustive_full_runs,
                op.racing_full_runs,
                op.racing_probe_runs,
                op.racing_pruned,
                op.front_size
            );
        }
        s
    }
}

/// Byte span of the VALUE of top-level `"key": …` in `json` — an
/// array/object matched bracket-wise (string-literal aware), or a
/// scalar up to the next comma/newline/closing brace. `None` if the key
/// is absent or its value is malformed.
fn section_span(json: &str, key: &str) -> Option<(usize, usize)> {
    let pat = format!("\"{key}\":");
    let at = json.find(&pat)?;
    let bytes = json.as_bytes();
    let mut i = at + pat.len();
    while i < bytes.len() && bytes[i].is_ascii_whitespace() {
        i += 1;
    }
    if i >= bytes.len() {
        return None;
    }
    let start = i;
    let (open, close) = match bytes[i] {
        b'[' => (b'[', b']'),
        b'{' => (b'{', b'}'),
        _ => {
            while i < bytes.len() && !matches!(bytes[i], b',' | b'\n' | b'}') {
                i += 1;
            }
            return Some((start, i));
        }
    };
    let mut depth = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    while i < bytes.len() {
        let c = bytes[i];
        if in_str {
            if escaped {
                escaped = false;
            } else if c == b'\\' {
                escaped = true;
            } else if c == b'"' {
                in_str = false;
            }
        } else if c == b'"' {
            in_str = true;
        } else if c == open {
            depth += 1;
        } else if c == close {
            depth -= 1;
            if depth == 0 {
                return Some((start, i + 1));
            }
        }
        i += 1;
    }
    None
}

/// Read-modify-write for `BENCH_noc.json` (`fabricflow bench --only`):
/// serialize `fresh`, then splice the UNSELECTED sections' value text
/// back in from `old_json`, so regenerating one section preserves the
/// others byte for byte. A section missing from the old file is left as
/// `fresh` emitted it (empty / null).
pub fn merge_sections(old_json: &str, fresh: &BenchReport, sel: BenchSelect) -> String {
    let mut out = fresh.to_json();
    for (key, selected) in [
        ("points", sel.points),
        ("multichip", sel.multichip),
        ("sweep", sel.sweep),
        ("serve", sel.serve),
        ("faults", sel.faults),
        ("bitsliced", sel.bitsliced),
        ("trace", sel.trace),
        ("optimize", sel.optimize),
    ] {
        if selected {
            continue;
        }
        // Spans are recomputed after each splice: earlier replacements
        // shift later offsets.
        if let (Some((os, oe)), Some((fs, fe))) =
            (section_span(old_json, key), section_span(&out, key))
        {
            out.replace_range(fs..fe, &old_json[os..oe]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels_are_unique_and_scenarios_exist() {
        let pts = points();
        for (i, a) in pts.iter().enumerate() {
            assert!(scenario::find(a.scenario).is_some(), "{}", a.label);
            for b in &pts[i + 1..] {
                assert_ne!(a.label, b.label);
            }
        }
        assert!(pts.iter().any(|p| p.label == "saturated-mesh8x8/uniform"));
    }

    #[test]
    fn one_point_runs_and_serializes() {
        // Tiny profile of the headline point: engines must agree and the
        // JSON must carry its label and throughput fields.
        let pt = BenchPoint {
            label: "saturated-mesh8x8/uniform",
            topo: Topology::Mesh { w: 4, h: 4 },
            scenario: "uniform",
            load: 0.3,
            window: 200,
        };
        let res = run_point(&pt, 1, 1.0);
        assert!(res.reference.flits > 0);
        assert_eq!(res.reference.flits, res.event.flits);
        assert_eq!(res.reference.cycles, res.event.cycles);
        let report = BenchReport {
            quick: true,
            points: vec![res],
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: None,
            trace: None,
            optimize: None,
        };
        let json = report.to_json();
        assert!(json.contains("\"label\": \"saturated-mesh8x8/uniform\""));
        assert!(json.contains("flits_per_sec"));
        assert!(json.contains("\"profile\": \"quick\""));
        assert!(json.contains("\"multichip\": ["));
        assert!(json.contains("\"sweep\": null,"));
        assert!(json.contains("\"serve\": null,"));
        assert!(json.contains("\"faults\": null,"));
        assert!(json.contains("\"bitsliced\": null,"));
        assert!(json.contains("\"trace\": null,"));
        assert!(json.contains("\"optimize\": null"));
        assert!(report.render_table().contains("saturated-mesh8x8"));
    }

    #[test]
    fn multichip_labels_are_unique_and_scenarios_exist() {
        let pts = multichip_points();
        assert_eq!(pts.len(), 3, "one point per case study");
        for (i, a) in pts.iter().enumerate() {
            assert!(scenario::find(a.scenario).is_some(), "{}", a.label);
            for b in &pts[i + 1..] {
                assert_ne!(a.label, b.label);
            }
        }
    }

    #[test]
    fn multichip_point_runs_and_serializes() {
        // A shrunk bmvm point: the sharded run must deliver the same
        // flit count, cost at least as many cycles, and serialize into
        // the multichip JSON section.
        let pt = MultiBenchPoint {
            label: "bmvm-ring8/2fpga-8pin",
            topo: Topology::Ring(8),
            scenario: "bmvm-trace",
            load: 0.1,
            window: 400,
            n_fpgas: 2,
            pins: 8,
            clock_div: 1,
        };
        let res = run_multichip_point(&pt, 1, 1.0);
        assert!(res.mono.flits > 0);
        assert_eq!(res.mono.flits, res.sharded.flits);
        assert!(res.cycle_slowdown() >= 1.0);
        let report = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: vec![res],
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: None,
            trace: None,
            optimize: None,
        };
        let json = report.to_json();
        assert!(json.contains("\"label\": \"bmvm-ring8/2fpga-8pin\""));
        assert!(json.contains("cycle_slowdown"));
        assert!(report.render_table().contains("sharded"));
    }

    fn sweep_stub() -> SweepBench {
        SweepBench {
            grid_jobs: 36,
            threads: 4,
            serial_jobs_per_sec: 100.0,
            parallel_jobs_per_sec: 310.0,
            parallel_speedup: 3.1,
            reuse_jobs: 16,
            rebuild_jobs_per_sec: 50.0,
            reuse_jobs_per_sec: 200.0,
            reuse_speedup: 4.0,
        }
    }

    fn serve_stub() -> ServeBench {
        ServeBench {
            threads: 2,
            queue_cap: 64,
            points: vec![
                ServePoint {
                    label: "poisson-500rps".into(),
                    offered_rps: 500.0,
                    requests: 60,
                    served: 60,
                    rejected: 0,
                    achieved_rps: 498.2,
                    p50_us: 210,
                    p95_us: 400,
                    p99_us: 700,
                    max_us: 900,
                    rejection_rate: 0.0,
                },
                ServePoint {
                    label: "flood".into(),
                    offered_rps: 0.0,
                    requests: 60,
                    served: 48,
                    rejected: 12,
                    achieved_rps: 9000.0,
                    p50_us: 150,
                    p95_us: 300,
                    p99_us: 500,
                    max_us: 650,
                    rejection_rate: 0.2,
                },
            ],
        }
    }

    fn faults_stub() -> FaultsBench {
        FaultsBench {
            scenario: "uniform",
            pins: 8,
            clock_div: 1,
            points: vec![
                FaultPoint {
                    rate: 0.0,
                    cycles: 1000,
                    delivered: 800,
                    retransmits: 0,
                    corrupted: 0,
                    goodput: 0.8,
                    overhead: 1.0,
                },
                FaultPoint {
                    rate: 0.01,
                    cycles: 1500,
                    delivered: 800,
                    retransmits: 40,
                    corrupted: 25,
                    goodput: 0.5333,
                    overhead: 1.5,
                },
            ],
        }
    }

    #[test]
    fn sweep_section_serializes_and_renders() {
        let report = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: Some(sweep_stub()),
            serve: None,
            faults: None,
            bitsliced: None,
            trace: None,
            optimize: None,
        };
        let json = report.to_json();
        assert!(json.contains("\"sweep\": {"));
        assert!(json.contains("\"parallel_speedup\": 3.10"));
        assert!(json.contains("\"reuse_speedup\": 4.00"));
        assert!(report.render_table().contains("Fleet sweep throughput"));
    }

    #[test]
    fn serve_section_serializes_and_renders() {
        let report = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: Some(serve_stub()),
            faults: None,
            bitsliced: None,
            trace: None,
            optimize: None,
        };
        let json = report.to_json();
        assert!(json.contains("\"serve\": {"));
        assert!(json.contains("\"label\": \"poisson-500rps\""));
        assert!(json.contains("\"p99_us\": 700"));
        assert!(json.contains("\"rejection_rate\": 0.2000"));
        let table = report.render_table();
        assert!(table.contains("Serving latency vs offered load"));
        assert!(table.contains("flood"));
    }

    #[test]
    fn faults_section_serializes_and_renders() {
        let report = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: Some(faults_stub()),
            bitsliced: None,
            trace: None,
            optimize: None,
        };
        let json = report.to_json();
        assert!(json.contains("\"faults\": {"));
        assert!(json.contains("\"rate\": 0.01,"));
        assert!(json.contains("\"retransmits\": 40,"));
        assert!(json.contains("\"overhead\": 1.500"));
        // The serve section before it must now carry a trailing comma.
        assert!(json.contains("\"serve\": null,"));
        let table = report.render_table();
        assert!(table.contains("Wire-fault recovery cost"));
        assert!(table.contains("retrans"));
    }

    #[test]
    fn bench_select_parses_only_flags() {
        let none = BenchSelect::NONE;
        assert_eq!(BenchSelect::parse("sweep"), Some(BenchSelect { sweep: true, ..none }));
        assert_eq!(BenchSelect::parse("serve"), Some(BenchSelect { serve: true, ..none }));
        assert_eq!(BenchSelect::parse("faults"), Some(BenchSelect { faults: true, ..none }));
        assert_eq!(
            BenchSelect::parse("bitsliced"),
            Some(BenchSelect { bitsliced: true, ..none })
        );
        assert_eq!(BenchSelect::parse("trace"), Some(BenchSelect { trace: true, ..none }));
        assert_eq!(
            BenchSelect::parse("optimize"),
            Some(BenchSelect { optimize: true, ..none })
        );
        assert_eq!(
            BenchSelect::parse("points,multichip"),
            Some(BenchSelect { points: true, multichip: true, ..none })
        );
        assert_eq!(
            BenchSelect::parse("points,multichip,sweep,serve,faults,bitsliced,trace,optimize"),
            Some(BenchSelect::ALL)
        );
        assert_ne!(
            BenchSelect::parse("points,multichip,sweep,serve,faults,bitsliced,trace"),
            Some(BenchSelect::ALL)
        );
        assert!(BenchSelect::ALL.is_all());
        assert_eq!(BenchSelect::parse("everything"), None);
    }

    #[test]
    fn merge_preserves_unselected_sections_byte_for_byte() {
        // An "old" file with real points and a sweep section.
        let old = BenchReport {
            quick: false,
            points: vec![PointResult {
                label: "saturated-mesh8x8/uniform",
                reference: CellResult {
                    engine: SimEngine::Reference,
                    wall_s: 0.5,
                    flits: 1000,
                    cycles: 4000,
                },
                event: CellResult {
                    engine: SimEngine::EventDriven,
                    wall_s: 0.25,
                    flits: 1000,
                    cycles: 4000,
                },
            }],
            multichip: Vec::new(),
            sweep: Some(sweep_stub()),
            serve: Some(serve_stub()),
            faults: Some(faults_stub()),
            bitsliced: None,
            trace: None,
            optimize: None,
        }
        .to_json();
        // A fresh sweep-only run: points/multichip empty, new sweep.
        let mut new_sweep = sweep_stub();
        new_sweep.parallel_speedup = 9.99;
        let fresh = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: Some(new_sweep),
            serve: None,
            faults: None,
            bitsliced: None,
            trace: None,
            optimize: None,
        };
        let sel = BenchSelect { sweep: true, ..BenchSelect::NONE };
        let merged = merge_sections(&old, &fresh, sel);
        // Old points preserved verbatim, new sweep spliced in.
        let (os, oe) = section_span(&old, "points").unwrap();
        let (ms, me) = section_span(&merged, "points").unwrap();
        assert_eq!(&old[os..oe], &merged[ms..me], "unselected section changed");
        assert!(merged.contains("\"label\": \"saturated-mesh8x8/uniform\""));
        assert!(merged.contains("\"parallel_speedup\": 9.99"));
        assert!(!merged.contains("\"parallel_speedup\": 3.10"));
        // The unselected serve section came through byte-for-byte too.
        let (os, oe) = section_span(&old, "serve").unwrap();
        let (ms, me) = section_span(&merged, "serve").unwrap();
        assert_eq!(&old[os..oe], &merged[ms..me], "serve section changed");
        // And the other way: regenerating points keeps the old sweep,
        // serve, and faults sections.
        let sel = BenchSelect { points: true, ..BenchSelect::NONE };
        let fresh_points = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: None,
            trace: None,
            optimize: None,
        };
        let merged = merge_sections(&old, &fresh_points, sel);
        assert!(merged.contains("\"parallel_speedup\": 3.10"));
        assert!(!merged.contains("\"sweep\": null"));
        assert!(merged.contains("\"label\": \"poisson-500rps\""));
        assert!(!merged.contains("\"serve\": null"));
        assert!(merged.contains("\"retransmits\": 40,"));
        assert!(!merged.contains("\"faults\": null"));
    }

    #[test]
    fn section_span_handles_the_placeholder_and_nesting() {
        let json = "{\n  \"note\": \"has [brackets] and {braces}\",\n  \"points\": [],\n  \"multichip\": [\n    { \"label\": \"a[0]\" }\n  ],\n  \"sweep\": null\n}\n";
        let (s, e) = section_span(json, "points").unwrap();
        assert_eq!(&json[s..e], "[]");
        let (s, e) = section_span(json, "multichip").unwrap();
        assert!(json[s..e].starts_with('[') && json[s..e].ends_with(']'));
        assert!(json[s..e].contains("a[0]"));
        let (s, e) = section_span(json, "sweep").unwrap();
        assert_eq!(&json[s..e], "null");
        assert!(section_span(json, "missing").is_none());
    }

    #[test]
    fn serve_bench_runs_tiny() {
        // A real quick serve bench: latencies are wall-clock, but the
        // accounting must reconcile at every point and the flood point
        // must exist (it is where admission control gets exercised).
        let sv = run_serve_bench(true);
        assert_eq!(sv.points.len(), 3, "two paced points + flood");
        assert_eq!(sv.points.last().unwrap().label, "flood");
        for p in &sv.points {
            assert_eq!(p.served + p.rejected, p.requests, "{}", p.label);
            assert!(p.achieved_rps > 0.0, "{}", p.label);
            // Percentile edges are clamped to the observed max, so the
            // whole quantile chain is ordered.
            assert!(p.p99_us >= p.p50_us, "{}", p.label);
            assert!(p.max_us >= p.p99_us, "{}", p.label);
            assert!(p.max_us > 0, "{}", p.label);
        }
    }

    #[test]
    fn faults_bench_runs_tiny() {
        // A real quick faults bench: the whole point of the section is
        // that delivery never degrades — only cycles do.
        let fb = run_faults_bench(true);
        assert_eq!(fb.points.len(), 4);
        assert_eq!(fb.points[0].rate, 0.0);
        assert_eq!(fb.points[0].overhead, 1.0);
        assert_eq!(fb.points[0].retransmits, 0, "clean row must not replay");
        let clean = &fb.points[0];
        for p in &fb.points {
            assert_eq!(p.delivered, clean.delivered, "rate {} lost flits", p.rate);
            assert!(p.overhead >= 1.0, "rate {}", p.rate);
            assert!(p.goodput <= clean.goodput + 1e-12, "rate {}", p.rate);
        }
        // CRC stretches the wire format even before any fault fires, so
        // every protected row costs strictly more than the clean one.
        for p in &fb.points[1..] {
            assert!(p.cycles > clean.cycles, "rate {} paid no protection cost", p.rate);
        }
        let top = fb.points.last().unwrap();
        assert!(top.retransmits > 0, "1% faults must force wire replays");
    }

    fn bitsliced_stub() -> BitslicedBench {
        BitslicedBench {
            code: "pg(2,4)",
            variant: "sign-magnitude",
            frames: 150,
            niter: 8,
            points: vec![
                BitslicedPoint {
                    lanes: 1,
                    scalar_seeds_per_sec: 900.0,
                    sliced_seeds_per_sec: 700.0,
                    speedup: 0.78,
                },
                BitslicedPoint {
                    lanes: 64,
                    scalar_seeds_per_sec: 900.0,
                    sliced_seeds_per_sec: 3600.0,
                    speedup: 4.0,
                },
            ],
        }
    }

    #[test]
    fn bitsliced_section_serializes_and_renders() {
        let report = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: Some(faults_stub()),
            bitsliced: Some(bitsliced_stub()),
            trace: None,
            optimize: None,
        };
        let json = report.to_json();
        assert!(json.contains("\"bitsliced\": {"));
        assert!(json.contains("\"code\": \"pg(2,4)\""));
        assert!(json.contains("\"lanes\": 64,"));
        assert!(json.contains("\"speedup\": 4.00"));
        // The faults section before it must now carry a trailing comma.
        assert!(json.contains("  },\n  \"bitsliced\""));
        let table = report.render_table();
        assert!(table.contains("Bitsliced Monte-Carlo"));
        assert!(table.contains("64 lanes"));
    }

    #[test]
    fn bitsliced_bench_runs_tiny() {
        // A real quick bitsliced bench at a shrunk frame count: the lane
        // bit-identity and the 64-lane ≥-scalar contract are asserted
        // inside the run; here we check the section's shape.
        let bs = run_bitsliced_bench(true);
        assert_eq!(bs.points.len(), 3);
        assert_eq!(
            bs.points.iter().map(|p| p.lanes).collect::<Vec<_>>(),
            vec![1, 8, 64]
        );
        for p in &bs.points {
            assert!(p.scalar_seeds_per_sec > 0.0, "{} lanes", p.lanes);
            assert!(p.sliced_seeds_per_sec > 0.0, "{} lanes", p.lanes);
            assert!(
                (p.speedup - p.sliced_seeds_per_sec / p.scalar_seeds_per_sec).abs() < 1e-9,
                "{} lanes",
                p.lanes
            );
        }
    }

    #[test]
    fn merge_splices_a_fresh_bitsliced_section_over_an_old_one() {
        let old = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: Some(bitsliced_stub()),
            trace: None,
            optimize: None,
        }
        .to_json();
        let mut newer = bitsliced_stub();
        newer.points[1].speedup = 7.77;
        let fresh = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: Some(newer),
            trace: None,
            optimize: None,
        };
        // bitsliced selected: the fresh section wins.
        let sel = BenchSelect::parse("bitsliced").unwrap();
        let merged = merge_sections(&old, &fresh, sel);
        assert!(merged.contains("\"speedup\": 7.77"));
        // bitsliced NOT selected: the old section survives byte for byte.
        let sel = BenchSelect::parse("points").unwrap();
        let merged = merge_sections(&old, &fresh, sel);
        assert!(merged.contains("\"speedup\": 4.00"));
        assert!(!merged.contains("\"speedup\": 7.77"));
    }

    fn trace_stub() -> TraceBench {
        TraceBench {
            scenario: "hotspot",
            cycles: 5000,
            events: 120_000,
            untraced_wall_ms: 10.0,
            traced_wall_ms: 12.0,
            trace_overhead: 1.2,
            static_cycles: 400,
            guided_cycles: 250,
            guided_speedup: 1.6,
        }
    }

    #[test]
    fn trace_section_serializes_and_renders() {
        let report = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: Some(bitsliced_stub()),
            trace: Some(trace_stub()),
            optimize: None,
        };
        let json = report.to_json();
        assert!(json.contains("\"trace\": {"));
        assert!(json.contains("\"trace_overhead\": 1.20"));
        assert!(json.contains("\"guided_speedup\": 1.60"));
        // The bitsliced section before it must now carry a trailing
        // comma.
        assert!(json.contains("  },\n  \"trace\""));
        let table = report.render_table();
        assert!(table.contains("Trace recorder"));
        assert!(table.contains("profile-guided placement"));
    }

    #[test]
    fn trace_bench_runs_tiny() {
        // A real quick trace bench: the digest bit-identity and the
        // guided < static contract are asserted inside the run; here we
        // check the section's numbers reconcile.
        let tr = run_trace_bench(true);
        assert_eq!(tr.scenario, "hotspot");
        assert!(tr.cycles > 0);
        assert!(tr.events > 0, "traced replay must record events");
        assert!(tr.untraced_wall_ms > 0.0 && tr.traced_wall_ms > 0.0);
        assert!(
            (tr.trace_overhead - tr.traced_wall_ms / tr.untraced_wall_ms).abs() < 1e-9
        );
        assert!(tr.guided_cycles < tr.static_cycles);
        assert!(tr.guided_speedup > 1.0);
    }

    #[test]
    fn merge_preserves_an_unselected_trace_section() {
        let old = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: None,
            trace: Some(trace_stub()),
            optimize: None,
        }
        .to_json();
        let fresh = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: None,
            trace: None,
            optimize: None,
        };
        let sel = BenchSelect::parse("points").unwrap();
        let merged = merge_sections(&old, &fresh, sel);
        let (os, oe) = section_span(&old, "trace").unwrap();
        let (ms, me) = section_span(&merged, "trace").unwrap();
        assert_eq!(&old[os..oe], &merged[ms..me], "trace section changed");
        assert!(merged.contains("\"guided_speedup\": 1.60"));
    }

    fn optimize_stub() -> OptimizeBench {
        OptimizeBench {
            scenario: "uniform",
            space_points: 8,
            threads: 4,
            front_size: 2,
            exhaustive_full_runs: 8,
            racing_full_runs: 0,
            racing_probe_runs: 12,
            racing_pruned: 2,
            sequential_evals_per_sec: 20.0,
            racing_evals_per_sec: 90.0,
            racing_speedup: 4.5,
        }
    }

    #[test]
    fn optimize_section_serializes_and_renders() {
        let report = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: None,
            trace: Some(trace_stub()),
            optimize: Some(optimize_stub()),
        };
        let json = report.to_json();
        assert!(json.contains("\"optimize\": {"));
        assert!(json.contains("\"racing_speedup\": 4.50"));
        assert!(json.contains("\"exhaustive_full_runs\": 8,"));
        // The trace section before it must now carry a trailing comma.
        assert!(json.contains("  },\n  \"optimize\""));
        let table = report.render_table();
        assert!(table.contains("Design-space autopilot"));
        assert!(table.contains("pruned"));
    }

    #[test]
    fn optimize_bench_runs_tiny() {
        // A real quick optimize bench: front equality and the saved
        // full-budget runs are asserted inside the run; here we check
        // the section's shape. Quick space: mesh2x2 × pins {1,8}.
        let op = run_optimize_bench(true);
        assert_eq!(op.scenario, "uniform");
        assert_eq!(op.space_points, 2);
        assert!(op.front_size >= 1);
        assert_eq!(op.exhaustive_full_runs, 2);
        assert!(op.racing_full_runs < op.exhaustive_full_runs);
        assert!(op.sequential_evals_per_sec > 0.0);
        assert!(op.racing_evals_per_sec > 0.0);
    }

    #[test]
    fn merge_preserves_an_unselected_optimize_section() {
        let old = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: None,
            trace: None,
            optimize: Some(optimize_stub()),
        }
        .to_json();
        let fresh = BenchReport {
            quick: true,
            points: Vec::new(),
            multichip: Vec::new(),
            sweep: None,
            serve: None,
            faults: None,
            bitsliced: None,
            trace: None,
            optimize: None,
        };
        let sel = BenchSelect { points: true, ..BenchSelect::NONE };
        let merged = merge_sections(&old, &fresh, sel);
        let (os, oe) = section_span(&old, "optimize").unwrap();
        let (ms, me) = section_span(&merged, "optimize").unwrap();
        assert_eq!(&old[os..oe], &merged[ms..me], "optimize section changed");
        assert!(merged.contains("\"racing_speedup\": 4.50"));
    }

    #[test]
    fn sweep_bench_runs_tiny() {
        // A real (tiny) sweep bench: speedups are wall-clock and may be
        // anything on a loaded CI box, but the run itself must complete
        // with coherent counts (thread invariance is asserted inside).
        let sw = run_sweep_bench(true);
        assert_eq!(sw.grid_jobs, 3 * 2 * 6);
        assert!(sw.threads >= 2);
        assert!(sw.serial_jobs_per_sec > 0.0);
        assert!(sw.parallel_jobs_per_sec > 0.0);
        assert!(sw.reuse_jobs_per_sec > 0.0);
        assert!(sw.rebuild_jobs_per_sec > 0.0);
    }
}
