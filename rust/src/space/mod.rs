//! Typed design-space axes for the autopilot (`fabricflow optimize`).
//!
//! The paper frames the framework as *semi-automated*: a human picks a
//! CONNECT topology, link pins, clock divider, buffer depth, and a
//! partition, then re-runs until the case study fits and performs. The
//! fleet sweep (PR 5) brute-forces grids, but the grid itself is still
//! an ad-hoc tuple baked into `perf.rs` / `scenario.rs::SweepGrid`. This
//! module generalizes those into shared, typed axes:
//!
//! * [`TopoSpec`] — an exactly re-encodable topology point (`mesh4x4`),
//!   unlike [`Topology`] which carries derived tables for `Custom`.
//! * [`Axis`] — one named dimension of the search, used for uniform
//!   validation (non-empty, duplicate-free, in-range).
//! * [`SearchSpace`] — the cross product, enumerated in a canonical
//!   deterministic order ([`SearchSpace::points`]).
//! * [`ConfigPoint`] — one coordinate, with **exact encode/decode**
//!   (`mesh4x4/p8/d1/b8/s1/c2` round-trips) and lossless lowering to a
//!   [`FlowBuilder`] configuration ([`ConfigPoint::apply_to`],
//!   [`ConfigPoint::builder_code`]).
//! * [`ConfigEstimate`] — the static (no-simulation) cost coordinates of
//!   a point: per-FPGA resource envelope from [`crate::resources`] and
//!   wire cost in pins. Monotone in routers, pins, and buffer depth —
//!   asserted by the tests below — so Pareto pruning on these axes is
//!   trustworthy.
//!
//! `rust/src/optimize/` races points of a [`SearchSpace`] against each
//! other; this module owns everything that is true of a point *before*
//! any simulation runs.

use std::fmt;

use crate::noc::topology::TopoGraph;
use crate::noc::{NocConfig, Topology};
use crate::partition::{Partition, PartitionError};
use crate::resources::Resources;
use crate::serdes::SerdesConfig;

/// A topology point that re-encodes exactly: unlike [`Topology`], every
/// variant is a pure value (no derived tables), so
/// `TopoSpec::decode(&spec.encode())` is the identity. The optimizer
/// searches over these and lowers to [`Topology`] only at build time.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum TopoSpec {
    /// `n` routers in a cycle (`ring8`).
    Ring(usize),
    /// `w × h` mesh (`mesh4x4`).
    Mesh { w: usize, h: usize },
    /// `w × h` torus (`torus4x4`).
    Torus { w: usize, h: usize },
}

impl TopoSpec {
    /// Lower to the simulator's [`Topology`].
    pub fn build_topology(&self) -> Topology {
        match *self {
            TopoSpec::Ring(n) => Topology::Ring(n),
            TopoSpec::Mesh { w, h } => Topology::Mesh { w, h },
            TopoSpec::Torus { w, h } => Topology::Torus { w, h },
        }
    }

    /// Endpoints (= routers for these families: one endpoint per router).
    pub fn n_endpoints(&self) -> usize {
        match *self {
            TopoSpec::Ring(n) => n,
            TopoSpec::Mesh { w, h } | TopoSpec::Torus { w, h } => w * h,
        }
    }

    /// Routers (identical to endpoints for these families; named
    /// separately because partitions assign *routers*).
    pub fn n_routers(&self) -> usize {
        self.n_endpoints()
    }

    /// Stable wire name: `ring8`, `mesh4x4`, `torus2x8`.
    pub fn encode(&self) -> String {
        match *self {
            TopoSpec::Ring(n) => format!("ring{n}"),
            TopoSpec::Mesh { w, h } => format!("mesh{w}x{h}"),
            TopoSpec::Torus { w, h } => format!("torus{w}x{h}"),
        }
    }

    /// Inverse of [`TopoSpec::encode`].
    pub fn decode(s: &str) -> Result<TopoSpec, SpaceError> {
        let bad = || SpaceError::BadTopo(s.to_string());
        if let Some(rest) = s.strip_prefix("ring") {
            let n: usize = rest.parse().map_err(|_| bad())?;
            if n < 2 {
                return Err(bad());
            }
            return Ok(TopoSpec::Ring(n));
        }
        let (family, rest) = if let Some(rest) = s.strip_prefix("mesh") {
            ("mesh", rest)
        } else if let Some(rest) = s.strip_prefix("torus") {
            ("torus", rest)
        } else {
            return Err(bad());
        };
        let (w, h) = rest.split_once('x').ok_or_else(bad)?;
        let w: usize = w.parse().map_err(|_| bad())?;
        let h: usize = h.parse().map_err(|_| bad())?;
        if w * h < 2 {
            return Err(bad());
        }
        Ok(match family {
            "mesh" => TopoSpec::Mesh { w, h },
            _ => TopoSpec::Torus { w, h },
        })
    }

    /// The Rust expression building this topology, for emitted
    /// `FlowBuilder` code.
    pub fn code(&self) -> String {
        match *self {
            TopoSpec::Ring(n) => format!("Topology::Ring({n})"),
            TopoSpec::Mesh { w, h } => format!("Topology::Mesh {{ w: {w}, h: {h} }}"),
            TopoSpec::Torus { w, h } => format!("Topology::Torus {{ w: {w}, h: {h} }}"),
        }
    }
}

impl fmt::Display for TopoSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.encode())
    }
}

/// One named dimension of a [`SearchSpace`], in a uniform shape so
/// validation (non-empty, duplicate-free, value ranges) is written once.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Axis {
    /// Topology family × size.
    Topo(Vec<TopoSpec>),
    /// Inter-FPGA link width in pins ([`SerdesConfig::pins`]).
    Pins(Vec<u32>),
    /// Off-chip clock divider ([`SerdesConfig::clock_div`]).
    ClockDiv(Vec<u32>),
    /// Router input-VC buffer depth ([`NocConfig::buffer_depth`]).
    BufferDepth(Vec<usize>),
    /// Seed of the bisection placer ([`Partition::balanced`]) — distinct
    /// seeds are distinct (deterministic) partitions of the same cut.
    PartSeed(Vec<u64>),
}

impl Axis {
    /// Axis name used in errors and CLI flags.
    pub fn name(&self) -> &'static str {
        match self {
            Axis::Topo(_) => "topos",
            Axis::Pins(_) => "pins",
            Axis::ClockDiv(_) => "clock-divs",
            Axis::BufferDepth(_) => "depths",
            Axis::PartSeed(_) => "part-seeds",
        }
    }

    /// Number of points along the axis.
    pub fn len(&self) -> usize {
        match self {
            Axis::Topo(v) => v.len(),
            Axis::Pins(v) => v.len(),
            Axis::ClockDiv(v) => v.len(),
            Axis::BufferDepth(v) => v.len(),
            Axis::PartSeed(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Display strings of the axis values, for duplicate detection and
    /// error messages.
    fn values(&self) -> Vec<String> {
        match self {
            Axis::Topo(v) => v.iter().map(|t| t.encode()).collect(),
            Axis::Pins(v) => v.iter().map(|x| x.to_string()).collect(),
            Axis::ClockDiv(v) => v.iter().map(|x| x.to_string()).collect(),
            Axis::BufferDepth(v) => v.iter().map(|x| x.to_string()).collect(),
            Axis::PartSeed(v) => v.iter().map(|x| x.to_string()).collect(),
        }
    }
}

/// A malformed [`SearchSpace`] or [`ConfigPoint`] encoding.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpaceError {
    /// Unparseable topology name.
    BadTopo(String),
    /// An axis has no values.
    EmptyAxis(&'static str),
    /// An axis lists the same value twice (would silently duplicate
    /// evaluations).
    DuplicateValue { axis: &'static str, value: String },
    /// A hardware axis value that must be ≥ 1 is 0.
    ZeroValue(&'static str),
    /// Buffer depth exceeds the flit arena's 16-bit ring index.
    DepthTooLarge(usize),
    /// A topology too small to host a scenario (scenarios need ≥ 2
    /// endpoints) or to split across `chips` FPGAs.
    TopoTooSmall { topo: String, chips: usize },
    /// A wire axis (pins / clock-divs / part-seeds) has multiple values
    /// but the search is monolithic — the axis would be a no-op.
    WireAxisOnMono(&'static str),
    /// A pinned-pair router index outside some topology of the space.
    PinOutOfRange { router: usize, topo: String },
    /// Unparseable [`ConfigPoint::encode`] string.
    BadPoint(String),
}

impl fmt::Display for SpaceError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpaceError::BadTopo(s) => {
                write!(f, "bad topology '{s}' (expected ringN, meshWxH, or torusWxH)")
            }
            SpaceError::EmptyAxis(a) => write!(f, "axis --{a} has no values"),
            SpaceError::DuplicateValue { axis, value } => {
                write!(f, "axis --{axis} lists '{value}' twice")
            }
            SpaceError::ZeroValue(a) => write!(f, "axis --{a} values must be >= 1"),
            SpaceError::DepthTooLarge(d) => {
                write!(f, "buffer depth {d} exceeds the 16-bit ring index")
            }
            SpaceError::TopoTooSmall { topo, chips } => {
                write!(f, "topology '{topo}' is too small (needs >= 2 endpoints and >= {chips} routers)")
            }
            SpaceError::WireAxisOnMono(a) => {
                write!(f, "axis --{a} has multiple values but --chips is 1 (wire axes need --chips >= 2)")
            }
            SpaceError::PinOutOfRange { router, topo } => {
                write!(f, "pinned router {router} out of range for topology '{topo}'")
            }
            SpaceError::BadPoint(s) => write!(f, "bad config point '{s}'"),
        }
    }
}

impl std::error::Error for SpaceError {}

/// The cross product of the autopilot's axes. [`SearchSpace::points`]
/// enumerates it in a canonical order (topology-major, then pins, clock
/// div, buffer depth, partition seed) so every consumer — exhaustive
/// evaluation, racing, any thread count — sees the identical indexing.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SearchSpace {
    pub topos: Vec<TopoSpec>,
    pub pins: Vec<u32>,
    pub clock_divs: Vec<u32>,
    pub buffer_depths: Vec<usize>,
    pub part_seeds: Vec<u64>,
    /// FPGAs to split across; 1 = monolithic (wire axes collapse).
    pub chips: usize,
    /// Router pairs that must share a chip
    /// ([`Partition::balanced_pinned`] constraints), applied to every
    /// point with `chips >= 2`.
    pub pinned: Vec<(usize, usize)>,
}

impl Default for SearchSpace {
    /// The paper's §VI-B defaults as a 1-point space: mesh4x4, 8 pins,
    /// same-clock links, depth-8 buffers, monolithic.
    fn default() -> Self {
        SearchSpace {
            topos: vec![TopoSpec::Mesh { w: 4, h: 4 }],
            pins: vec![8],
            clock_divs: vec![1],
            buffer_depths: vec![8],
            part_seeds: vec![1],
            chips: 1,
            pinned: Vec::new(),
        }
    }
}

impl SearchSpace {
    /// The axes in canonical (enumeration) order.
    pub fn axes(&self) -> [Axis; 5] {
        [
            Axis::Topo(self.topos.clone()),
            Axis::Pins(self.pins.clone()),
            Axis::ClockDiv(self.clock_divs.clone()),
            Axis::BufferDepth(self.buffer_depths.clone()),
            Axis::PartSeed(self.part_seeds.clone()),
        ]
    }

    /// Validate every axis: non-empty, duplicate-free, hardware values
    /// ≥ 1, topologies big enough for scenarios and for `chips`-way
    /// splits, pinned routers in range everywhere, and wire axes
    /// collapsed to singletons when monolithic.
    pub fn validate(&self) -> Result<(), SpaceError> {
        for axis in self.axes() {
            if axis.is_empty() {
                return Err(SpaceError::EmptyAxis(axis.name()));
            }
            let values = axis.values();
            for (i, v) in values.iter().enumerate() {
                if values[..i].contains(v) {
                    return Err(SpaceError::DuplicateValue {
                        axis: axis.name(),
                        value: v.clone(),
                    });
                }
            }
        }
        if self.pins.contains(&0) {
            return Err(SpaceError::ZeroValue("pins"));
        }
        if self.clock_divs.contains(&0) {
            return Err(SpaceError::ZeroValue("clock-divs"));
        }
        if self.buffer_depths.contains(&0) {
            return Err(SpaceError::ZeroValue("depths"));
        }
        if let Some(&d) = self.buffer_depths.iter().find(|&&d| d > u16::MAX as usize) {
            return Err(SpaceError::DepthTooLarge(d));
        }
        for t in &self.topos {
            if t.n_endpoints() < 2 || t.n_routers() < self.chips.max(1) {
                return Err(SpaceError::TopoTooSmall {
                    topo: t.encode(),
                    chips: self.chips.max(1),
                });
            }
            for &(a, b) in &self.pinned {
                for r in [a, b] {
                    if r >= t.n_routers() {
                        return Err(SpaceError::PinOutOfRange { router: r, topo: t.encode() });
                    }
                }
            }
        }
        if self.chips < 2 {
            for (name, len) in [
                ("pins", self.pins.len()),
                ("clock-divs", self.clock_divs.len()),
                ("part-seeds", self.part_seeds.len()),
            ] {
                if len > 1 {
                    return Err(SpaceError::WireAxisOnMono(name));
                }
            }
        }
        Ok(())
    }

    /// Size of the cross product.
    pub fn len(&self) -> usize {
        self.topos.len()
            * self.pins.len()
            * self.clock_divs.len()
            * self.buffer_depths.len()
            * self.part_seeds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Enumerate every [`ConfigPoint`] in canonical order.
    pub fn points(&self) -> Vec<ConfigPoint> {
        let mut out = Vec::with_capacity(self.len());
        for &topo in &self.topos {
            for &pins in &self.pins {
                for &clock_div in &self.clock_divs {
                    for &buffer_depth in &self.buffer_depths {
                        for &part_seed in &self.part_seeds {
                            out.push(ConfigPoint {
                                topo,
                                pins,
                                clock_div,
                                buffer_depth,
                                part_seed,
                                chips: self.chips.max(1),
                            });
                        }
                    }
                }
            }
        }
        out
    }
}

/// One coordinate of a [`SearchSpace`]: everything needed to build the
/// fabric (and partition, when multi-chip) exactly — encode/decode and
/// the lowering to [`crate::flow::FlowBuilder`] are lossless.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ConfigPoint {
    pub topo: TopoSpec,
    pub pins: u32,
    pub clock_div: u32,
    pub buffer_depth: usize,
    pub part_seed: u64,
    /// FPGAs; 1 = monolithic (pins/clock_div/part_seed are inert).
    pub chips: usize,
}

impl ConfigPoint {
    /// The point's [`NocConfig`]: `base` with this point's buffer depth.
    pub fn noc_config(&self, base: &NocConfig) -> NocConfig {
        NocConfig { buffer_depth: self.buffer_depth, ..*base }
    }

    /// The point's wire config. The TX buffer mirrors the router flit
    /// buffer depth (the repo-wide default convention).
    pub fn serdes(&self) -> SerdesConfig {
        SerdesConfig {
            pins: self.pins,
            clock_div: self.clock_div,
            tx_buffer: self.buffer_depth,
        }
    }

    /// The point's partition: `None` when monolithic, otherwise the
    /// seeded bisection placer (pinned-constrained when `pinned` is
    /// non-empty). Deterministic in `(topo, chips, part_seed, pinned)`.
    pub fn partition(
        &self,
        graph: &TopoGraph,
        pinned: &[(usize, usize)],
    ) -> Result<Option<Partition>, PartitionError> {
        if self.chips < 2 {
            return Ok(None);
        }
        if pinned.is_empty() {
            Ok(Some(Partition::balanced(graph, self.chips, self.part_seed)))
        } else {
            Partition::balanced_pinned(graph, self.chips, self.part_seed, pinned).map(Some)
        }
    }

    /// Stable wire name: `mesh4x4/p8/d1/b8/s1/c2`.
    pub fn encode(&self) -> String {
        format!(
            "{}/p{}/d{}/b{}/s{}/c{}",
            self.topo.encode(),
            self.pins,
            self.clock_div,
            self.buffer_depth,
            self.part_seed,
            self.chips
        )
    }

    /// Inverse of [`ConfigPoint::encode`].
    pub fn decode(s: &str) -> Result<ConfigPoint, SpaceError> {
        let bad = || SpaceError::BadPoint(s.to_string());
        let mut parts = s.split('/');
        let topo = TopoSpec::decode(parts.next().ok_or_else(bad)?)?;
        let mut num = |prefix: &str| -> Result<u64, SpaceError> {
            let p = parts.next().ok_or_else(bad)?;
            p.strip_prefix(prefix).ok_or_else(bad)?.parse().map_err(|_| bad())
        };
        let pins = num("p")? as u32;
        let clock_div = num("d")? as u32;
        let buffer_depth = num("b")? as usize;
        let part_seed = num("s")?;
        let chips = num("c")? as usize;
        if parts.next().is_some() {
            return Err(bad());
        }
        Ok(ConfigPoint { topo, pins, clock_div, buffer_depth, part_seed, chips })
    }

    /// Lower the point onto a [`crate::flow::FlowBuilder`]: topology,
    /// NoC config, and — when multi-chip — the seeded partition plus
    /// serializing wire channels. The builder's PEs/taps/channels are
    /// untouched; this is exactly the knob set the autopilot searches.
    pub fn apply_to(
        &self,
        fb: &mut crate::flow::FlowBuilder,
        base: &NocConfig,
        pinned: &[(usize, usize)],
    ) -> Result<(), PartitionError> {
        fb.topology(self.topo.build_topology());
        fb.noc(self.noc_config(base));
        if self.chips >= 2 {
            let graph = self.topo.build_topology().build();
            let part = self
                .partition(&graph, pinned)?
                .expect("chips >= 2 yields a partition");
            fb.partition(part);
            fb.multichip(self.serdes());
        }
        Ok(())
    }

    /// Emit the `FlowBuilder` call chain reproducing this point, for
    /// `fabricflow optimize`'s "winning config as code" output.
    pub fn builder_code(&self, base: &NocConfig) -> String {
        let mut out = String::new();
        out.push_str(&format!("fb.topology({});\n", self.topo.code()));
        out.push_str(&format!(
            "fb.noc(NocConfig {{ buffer_depth: {}, ..NocConfig::paper() }});\n",
            self.noc_config(base).buffer_depth
        ));
        if self.chips >= 2 {
            out.push_str(&format!("fb.seed({});\n", self.part_seed));
            out.push_str(&format!("fb.auto_partition({});\n", self.chips));
            out.push_str(&format!(
                "fb.multichip(SerdesConfig {{ pins: {}, clock_div: {}, tx_buffer: {} }});\n",
                self.pins, self.clock_div, self.buffer_depth
            ));
        }
        out
    }

    /// JSON object of the point, for `fabricflow optimize --json` and
    /// the BENCH section.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"topo\": \"{}\", \"pins\": {}, \"clock_div\": {}, \"buffer_depth\": {}, \"part_seed\": {}, \"chips\": {}}}",
            self.topo.encode(),
            self.pins,
            self.clock_div,
            self.buffer_depth,
            self.part_seed,
            self.chips
        )
    }

    /// Static cost coordinates: the per-FPGA resource **envelope**
    /// (componentwise max over chips — each FPGA must individually fit)
    /// and the wire cost in total pins across all chips. Monotone: more
    /// routers, wider pins, or deeper buffers never estimate fewer
    /// LUTs/regs/BRAM bits (asserted by this module's tests), which is
    /// what makes Pareto pruning on these axes sound.
    pub fn estimate(
        &self,
        graph: &TopoGraph,
        partition: Option<&Partition>,
        base: &NocConfig,
    ) -> ConfigEstimate {
        let cfg = self.noc_config(base);
        match partition {
            None => ConfigEstimate {
                per_fpga: graph.router_resources(&cfg),
                wire_pins: 0,
                cut_links: 0,
            },
            Some(part) => {
                let serdes = self.serdes();
                let per_chip = part.noc_resources_per_fpga(graph, &cfg, &serdes);
                let per_fpga = per_chip
                    .iter()
                    .fold(Resources::ZERO, |acc, r| acc.max_with(r));
                let wire_pins =
                    part.pins_per_fpga(graph, &serdes).iter().map(|&p| p as u64).sum();
                ConfigEstimate {
                    per_fpga,
                    wire_pins,
                    cut_links: part.cut_links(graph).len(),
                }
            }
        }
    }
}

impl fmt::Display for ConfigPoint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.encode())
    }
}

/// Static cost coordinates of a [`ConfigPoint`] (everything except
/// completion cycles, which need a simulation).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ConfigEstimate {
    /// Componentwise max over chips of the NoC+SERDES cost — the
    /// envelope every FPGA of the design must fit.
    pub per_fpga: Resources,
    /// Total FPGA pins committed to inter-chip wires (0 when
    /// monolithic).
    pub wire_pins: u64,
    /// Inter-chip links cut by the partition.
    pub cut_links: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_chip_space() -> SearchSpace {
        SearchSpace {
            topos: vec![TopoSpec::Mesh { w: 2, h: 2 }, TopoSpec::Mesh { w: 4, h: 4 }],
            pins: vec![1, 8],
            clock_divs: vec![1],
            buffer_depths: vec![4, 8],
            part_seeds: vec![1],
            chips: 2,
            pinned: Vec::new(),
        }
    }

    #[test]
    fn topo_spec_round_trips() {
        for spec in [
            TopoSpec::Ring(8),
            TopoSpec::Mesh { w: 2, h: 2 },
            TopoSpec::Mesh { w: 5, h: 3 },
            TopoSpec::Torus { w: 4, h: 4 },
        ] {
            assert_eq!(TopoSpec::decode(&spec.encode()), Ok(spec));
        }
        assert!(TopoSpec::decode("mesh4").is_err());
        assert!(TopoSpec::decode("ring1").is_err());
        assert!(TopoSpec::decode("hypercube8").is_err());
    }

    #[test]
    fn config_point_round_trips() {
        for p in two_chip_space().points() {
            assert_eq!(ConfigPoint::decode(&p.encode()), Ok(p));
        }
        assert!(ConfigPoint::decode("mesh4x4/p8/d1").is_err());
        assert!(ConfigPoint::decode("mesh4x4/p8/d1/b8/s1/c2/x9").is_err());
    }

    #[test]
    fn points_enumerate_the_full_product_in_canonical_order() {
        let space = two_chip_space();
        let points = space.points();
        assert_eq!(points.len(), space.len());
        assert_eq!(points.len(), 2 * 2 * 1 * 2 * 1);
        // Topology-major: first half all mesh2x2, second half mesh4x4.
        assert!(points[..4].iter().all(|p| p.topo == TopoSpec::Mesh { w: 2, h: 2 }));
        assert!(points[4..].iter().all(|p| p.topo == TopoSpec::Mesh { w: 4, h: 4 }));
        // Deterministic: the same space enumerates identically.
        assert_eq!(points, two_chip_space().points());
    }

    #[test]
    fn validate_rejects_malformed_spaces() {
        let ok = two_chip_space();
        assert_eq!(ok.validate(), Ok(()));

        let mut empty = ok.clone();
        empty.pins.clear();
        assert_eq!(empty.validate(), Err(SpaceError::EmptyAxis("pins")));

        let mut dup = ok.clone();
        dup.pins = vec![8, 8];
        assert_eq!(
            dup.validate(),
            Err(SpaceError::DuplicateValue { axis: "pins", value: "8".into() })
        );

        let mut zero = ok.clone();
        zero.clock_divs = vec![0];
        assert_eq!(zero.validate(), Err(SpaceError::ZeroValue("clock-divs")));

        let mut mono = ok.clone();
        mono.chips = 1;
        assert_eq!(mono.validate(), Err(SpaceError::WireAxisOnMono("pins")));

        let mut pin = ok.clone();
        pin.pinned = vec![(0, 99)];
        assert!(matches!(pin.validate(), Err(SpaceError::PinOutOfRange { router: 99, .. })));

        let mut small = ok;
        small.topos = vec![TopoSpec::Ring(2)];
        small.chips = 3;
        assert!(matches!(small.validate(), Err(SpaceError::TopoTooSmall { .. })));
    }

    #[test]
    fn estimate_is_monotone_in_routers() {
        let base = NocConfig::paper();
        let mk = |spec: TopoSpec| {
            let point = ConfigPoint {
                topo: spec,
                pins: 8,
                clock_div: 1,
                buffer_depth: 8,
                part_seed: 1,
                chips: 1,
            };
            point.estimate(&spec.build_topology().build(), None, &base)
        };
        let small = mk(TopoSpec::Mesh { w: 2, h: 2 });
        let mid = mk(TopoSpec::Mesh { w: 3, h: 3 });
        let big = mk(TopoSpec::Mesh { w: 4, h: 4 });
        assert!(small.per_fpga.luts < mid.per_fpga.luts);
        assert!(mid.per_fpga.luts < big.per_fpga.luts);
        assert!(small.per_fpga.regs < mid.per_fpga.regs);
        assert!(mid.per_fpga.bram_bits <= big.per_fpga.bram_bits);
    }

    #[test]
    fn estimate_is_monotone_in_pins_and_depth() {
        let base = NocConfig::paper();
        let spec = TopoSpec::Mesh { w: 2, h: 2 };
        let graph = spec.build_topology().build();
        let mk = |pins: u32, depth: usize| {
            let point = ConfigPoint {
                topo: spec,
                pins,
                clock_div: 1,
                buffer_depth: depth,
                part_seed: 1,
                chips: 2,
            };
            let part = point.partition(&graph, &[]).unwrap().unwrap();
            point.estimate(&graph, Some(&part), &base)
        };
        // Wider pins: never fewer LUTs, strictly more wire pins.
        let mut prev = mk(1, 8);
        for pins in [2, 4, 8, 16] {
            let cur = mk(pins, 8);
            assert!(prev.per_fpga.fits_within(&cur.per_fpga), "pins {pins} shrank the estimate");
            assert!(cur.wire_pins > prev.wire_pins);
            prev = cur;
        }
        // Deeper buffers: never fewer LUTs/BRAM bits, same wire pins.
        let shallow = mk(8, 4);
        let deep = mk(8, 16);
        assert!(shallow.per_fpga.fits_within(&deep.per_fpga));
        assert_eq!(shallow.wire_pins, deep.wire_pins);
    }

    #[test]
    fn pinned_partition_respects_constraints() {
        let spec = TopoSpec::Mesh { w: 2, h: 2 };
        let graph = spec.build_topology().build();
        let point = ConfigPoint {
            topo: spec,
            pins: 8,
            clock_div: 1,
            buffer_depth: 8,
            part_seed: 1,
            chips: 2,
        };
        let part = point.partition(&graph, &[(0, 3)]).unwrap().unwrap();
        assert_eq!(part.assignment[0], part.assignment[3]);
        // Monolithic points have no partition.
        let mono = ConfigPoint { chips: 1, ..point };
        assert_eq!(mono.partition(&graph, &[]).unwrap(), None);
    }

    /// A do-nothing processor so the lowering test can `build()` a flow.
    struct Quiet;
    impl crate::pe::Processor for Quiet {
        fn spec(&self) -> crate::pe::WrapperSpec {
            crate::pe::WrapperSpec::new(vec![8], vec![16])
        }
        fn process(
            &mut self,
            _args: &[crate::pe::collector::ArgMessage],
            _epoch: u32,
            _out: &mut crate::pe::MsgSink,
        ) {
        }
    }

    #[test]
    fn builder_lowering_is_exact() {
        use crate::flow::FlowBuilder;
        let point = ConfigPoint {
            topo: TopoSpec::Mesh { w: 2, h: 2 },
            pins: 4,
            clock_div: 2,
            buffer_depth: 16,
            part_seed: 1,
            chips: 2,
        };
        let base = NocConfig::paper();
        let mut fb = FlowBuilder::new("space-lowering");
        point.apply_to(&mut fb, &base, &[]).unwrap();
        fb.pe("src", Box::new(Quiet));
        fb.tap("sink");
        fb.channel("src", "sink");
        let flow = fb.build().unwrap();
        let part = flow.partition().expect("multichip point must partition");
        let graph = point.topo.build_topology().build();
        let expect = point.partition(&graph, &[]).unwrap().unwrap();
        assert_eq!(part.assignment, expect.assignment);
        let code = point.builder_code(&base);
        assert!(code.contains("Topology::Mesh { w: 2, h: 2 }"));
        assert!(code.contains("pins: 4"));
        assert!(code.contains("buffer_depth: 16"));
    }
}
