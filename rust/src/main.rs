//! fabricflow — command-line launcher for the framework.
//!
//! ```text
//! fabricflow tables --id all            # regenerate paper Tables I–V
//! fabricflow ldpc --niter 10 --flip 3   # Fig 9 decode over the NoC
//! fabricflow track --frames 8           # Fig 10 tracking over the NoC
//! fabricflow bmvm --topo torus --r 100  # §VI BMVM on a topology
//! fabricflow dfg --cores 4              # Fig 2 DFG→MIPS flow
//! fabricflow noc --topo mesh8x8         # raw NoC traffic experiment
//! fabricflow scenarios --topo mesh8x8   # scenario matrix (engine-selectable)
//! fabricflow scenarios --chips 2        # …sharded across FPGAs (multichip co-sim)
//! fabricflow trace --scenario hotspot   # flit-event recorder: links, channels, latency split
//! fabricflow trace --chips 2 --json     # …sharded, machine-readable
//! fabricflow sweep --threads 8          # fleet: scenario × load × seed grid
//! fabricflow sweep --chips 2 --pins 1,8 # …multichip grid across wire configs
//! fabricflow sweep --chips 2 --fault-rates 0,0.01   # …degraded wires (CRC/retransmit)
//! fabricflow sweep --lanes 8            # …8 Monte-Carlo lanes per listed seed
//! fabricflow optimize --chips 2         # autopilot: Pareto search over topology × pins × partition
//! fabricflow optimize --topos mesh2x2,mesh4x4 --depths 4,8 --json   # …machine-readable front
//! fabricflow bench --out BENCH_noc.json # tracked NoC benchmark matrix
//! fabricflow bench --only sweep         # …regenerate one section, keep the rest
//! fabricflow serve --threads 2          # resident pool serving request frames
//! fabricflow loadgen --rate 500 | fabricflow serve   # open-loop pipe
//! fabricflow partition                  # Fig 5 quasi-SERDES demo
//! fabricflow resources                  # device + component inventory
//! ```
//!
//! (clap is unavailable in the offline container; flags are parsed by
//! the strict [`args`] helper: unknown flags, positional arguments, and
//! unparsable values all print the subcommand's usage to stderr and
//! exit 2 instead of being silently ignored or panicking.)

use fabricflow::apps::bmvm::{dense_power_matvec, BmvmSystem, WilliamsLuts};
use fabricflow::apps::ldpc::mapper::LdpcNocDecoder;
use fabricflow::apps::ldpc::minsum::{codeword_llrs, MinsumVariant};
use fabricflow::apps::pfilter::{synthetic_video, PfilterNocTracker, TrackerParams};
use fabricflow::gf2::Gf2Matrix;
use fabricflow::noc::{scenario, Flit, Network, NocConfig, SimEngine, Topology};
use fabricflow::resources::Device;
use fabricflow::serdes::SerdesConfig;
use fabricflow::serve::{self, loadgen};
use fabricflow::tables::{self, TableOpts};
use fabricflow::util::args::{self, flag, switch, ArgSpec, Parsed};
use fabricflow::util::bits::BitVec;
use fabricflow::util::Rng;
use fabricflow::{dfg, mips, partition::Partition};

/// One subcommand: its flag table and usage line.
struct Command {
    name: &'static str,
    spec: &'static [ArgSpec],
    usage: &'static str,
    run: fn(&Parsed) -> Result<(), String>,
}

const COMMANDS: &[Command] = &[
    Command {
        name: "tables",
        spec: &[flag("id"), flag("reps"), flag("seed"), switch("quick")],
        usage: "tables [--id t1..t5|all] [--reps N] [--seed S] [--quick]",
        run: cmd_tables,
    },
    Command {
        name: "ldpc",
        spec: &[flag("niter"), flag("variant"), flag("flip"), switch("partition")],
        usage: "ldpc [--niter N] [--variant sm|paper] [--flip i,j,…] [--partition]",
        run: cmd_ldpc,
    },
    Command {
        name: "track",
        spec: &[
            flag("frames"),
            flag("workers"),
            flag("particles"),
            flag("sigma"),
            flag("roi"),
            flag("seed"),
            flag("vseed"),
        ],
        usage: "track [--frames N] [--workers N] [--particles N] [--sigma F] [--roi R] [--seed S] [--vseed S]",
        run: cmd_track,
    },
    Command {
        name: "bmvm",
        spec: &[flag("n"), flag("k"), flag("pes"), flag("r"), flag("topo"), flag("seed")],
        usage: "bmvm [--n N] [--k K] [--pes P] [--r R] [--topo ring|mesh|torus|fat-tree] [--seed S]",
        run: cmd_bmvm,
    },
    Command {
        name: "dfg",
        spec: &[flag("cores"), flag("file")],
        usage: "dfg [--cores N] [--file PROGRAM]",
        run: cmd_dfg,
    },
    Command {
        name: "noc",
        spec: &[flag("endpoints"), flag("topo"), flag("flits"), flag("seed")],
        usage: "noc [--endpoints N] [--topo NAME] [--flits N] [--seed S]",
        run: cmd_noc,
    },
    Command {
        name: "scenarios",
        spec: &[
            flag("endpoints"),
            flag("topo"),
            flag("engine"),
            flag("load"),
            flag("cycles"),
            flag("seed"),
            flag("scenario"),
            flag("chips"),
            flag("pins"),
            flag("clock-div"),
        ],
        usage: "scenarios [--topo NAME] [--engine reference|event] [--load F] [--cycles N] [--seed S] [--scenario NAME] [--chips N --pins P --clock-div D]",
        run: cmd_scenarios,
    },
    Command {
        name: "trace",
        spec: &[
            flag("endpoints"),
            flag("topo"),
            flag("engine"),
            flag("scenario"),
            flag("load"),
            flag("cycles"),
            flag("seed"),
            flag("chips"),
            flag("pins"),
            flag("clock-div"),
            flag("capacity"),
            flag("top"),
            switch("json"),
        ],
        usage: "trace [--topo NAME] [--engine reference|event] [--scenario NAME] [--load F] [--cycles N] [--seed S] [--chips N --pins P --clock-div D] [--capacity N] [--top N] [--json]",
        run: cmd_trace,
    },
    Command {
        name: "sweep",
        spec: &[
            flag("endpoints"),
            flag("topo"),
            flag("engine"),
            flag("threads"),
            flag("cycles"),
            flag("loads"),
            flag("seeds"),
            flag("lanes"),
            flag("seed"),
            flag("scenario"),
            flag("chips"),
            flag("pins"),
            flag("clock-divs"),
            flag("fault-rates"),
        ],
        usage: "sweep [--topo NAME] [--engine reference|event] [--threads N] [--cycles N] [--loads a,b] [--seeds N] [--lanes N] [--scenario NAME] [--chips N --pins p1,p2 --clock-divs d1,d2 --fault-rates r1,r2]",
        run: cmd_sweep,
    },
    Command {
        name: "optimize",
        spec: &[
            flag("scenario"),
            flag("topos"),
            flag("pins"),
            flag("clock-divs"),
            flag("depths"),
            flag("part-seeds"),
            flag("chips"),
            flag("load"),
            flag("cycles"),
            flag("seed"),
            flag("threads"),
            flag("probe"),
            flag("budget"),
            flag("sweeps"),
            flag("sa-iters"),
            flag("engine"),
            switch("exhaustive"),
            switch("json"),
        ],
        usage: "optimize [--scenario NAME] [--topos t1,t2] [--chips N] [--pins p1,p2] [--clock-divs d1,d2] [--depths b1,b2] [--part-seeds s1,s2] [--load F] [--cycles N] [--seed S] [--threads N] [--probe N] [--budget N] [--sweeps N] [--sa-iters N] [--engine reference|event] [--exhaustive] [--json]",
        run: cmd_optimize,
    },
    Command {
        name: "bench",
        spec: &[flag("out"), flag("only"), switch("quick")],
        usage: "bench [--quick] [--out FILE|-] [--only points,multichip,sweep,serve,faults,bitsliced,trace,optimize]",
        run: cmd_bench,
    },
    Command {
        name: "serve",
        spec: &[
            flag("threads"),
            flag("queue"),
            flag("admission"),
            flag("topo"),
            flag("endpoints"),
            flag("uds"),
            flag("bmvm-n"),
            flag("bmvm-k"),
            flag("bmvm-pes"),
            flag("bmvm-topo"),
            flag("bmvm-seed"),
            switch("fail-on-reject"),
        ],
        usage: "serve [--threads N] [--queue CAP] [--admission block|reject] [--topo NAME] [--uds PATH] [--bmvm-n N --bmvm-k K --bmvm-pes P --bmvm-topo NAME --bmvm-seed S] [--fail-on-reject]",
        run: cmd_serve,
    },
    Command {
        name: "loadgen",
        spec: &[
            flag("requests"),
            flag("rate"),
            flag("seed"),
            flag("mix"),
            flag("arrivals"),
            flag("on-ms"),
            flag("off-ms"),
            flag("bmvm-n"),
            switch("max-speed"),
        ],
        usage: "loadgen [--requests N] [--rate RPS] [--seed S] [--mix scenario,ldpc,pfilter,bmvm] [--arrivals poisson|bursty --on-ms N --off-ms N] [--bmvm-n N] [--max-speed]",
        run: cmd_loadgen,
    },
    Command {
        name: "partition",
        spec: &[flag("pins"), flag("clock-div")],
        usage: "partition [--pins P] [--clock-div D]",
        run: cmd_partition_demo,
    },
    Command { name: "resources", spec: &[], usage: "resources", run: cmd_resources },
];

fn topo_from_name(name: &str, endpoints: usize) -> Result<Topology, String> {
    match name {
        "ring" => Ok(Topology::Ring(endpoints)),
        "mesh" | "torus" => {
            let side = (endpoints as f64).sqrt().ceil() as usize;
            let h = endpoints.div_ceil(side);
            Ok(if name == "mesh" {
                Topology::Mesh { w: side, h }
            } else {
                Topology::Torus { w: side, h }
            })
        }
        "fat_tree" => Ok(Topology::fat_tree(endpoints)),
        other => {
            // meshWxH / torusWxH
            for (prefix, is_torus) in [("mesh", false), ("torus", true)] {
                if let Some(dims) = other.strip_prefix(prefix) {
                    if let Some((w, h)) = dims.split_once('x') {
                        if let (Ok(w), Ok(h)) = (w.parse(), h.parse()) {
                            return Ok(if is_torus {
                                Topology::Torus { w, h }
                            } else {
                                Topology::Mesh { w, h }
                            });
                        }
                    }
                }
            }
            Err(format!("unknown topology '{other}' (ring, mesh, torus, fat_tree, meshWxH, torusWxH)"))
        }
    }
}

fn engine_from_name(name: &str) -> Result<SimEngine, String> {
    match name {
        "ref" | "reference" => Ok(SimEngine::Reference),
        "event" | "event-driven" => Ok(SimEngine::EventDriven),
        other => Err(format!("unknown engine '{other}' (reference, event)")),
    }
}

fn bad(e: args::ArgError) -> String {
    e.to_string()
}

fn cmd_tables(p: &Parsed) -> Result<(), String> {
    let opts = TableOpts {
        reps: p.get_or("reps", 3usize).map_err(bad)?,
        quick: p.has("quick"),
        seed: p.get_or("seed", 0x7AB1Eu64).map_err(bad)?,
    };
    match p.raw("id").unwrap_or("all") {
        "t1" => print!("{}", tables::table1()),
        "t2" => print!("{}", tables::table2()),
        "t3" => print!("{}", tables::table3()),
        "t4" => print!("{}", tables::table4(&opts)),
        "t5" => print!("{}", tables::table5(&opts)),
        "all" => print!("{}", tables::all_tables(&opts)),
        other => return Err(format!("unknown table id '{other}' (t1..t5, all)")),
    }
    Ok(())
}

fn cmd_ldpc(p: &Parsed) -> Result<(), String> {
    let niter = p.get_or("niter", 10u32).map_err(bad)?;
    let variant = match p.raw("variant").unwrap_or("sm") {
        "paper" => MinsumVariant::PaperListing,
        "sm" => MinsumVariant::SignMagnitude,
        other => return Err(format!("unknown variant '{other}' (sm, paper)")),
    };
    let flips: Vec<usize> = p.get_list("flip").map_err(bad)?.unwrap_or_default();
    let dec = LdpcNocDecoder::fano_on_mesh(variant, niter);
    let llr = codeword_llrs(&[0; 7], 100, &flips);
    println!("LDPC Fano decode over 4x4 mesh, niter={niter}, flips={flips:?}");
    let run = dec.decode(&llr, None);
    println!(
        "  single FPGA : bits {:?} valid={} cycles={} flits={}",
        run.result.bits,
        run.result.valid_codeword,
        run.report.cycles,
        run.report.net.delivered
    );
    if p.has("partition") {
        let part = dec.fig9_partition();
        let split = dec.decode(&llr, Some((&part, SerdesConfig::default())));
        println!(
            "  2 FPGAs     : bits {:?} cycles={} (+{} serdes cycles)",
            split.result.bits,
            split.report.cycles,
            split.report.cycles - run.report.cycles
        );
    }
    Ok(())
}

fn cmd_track(p: &Parsed) -> Result<(), String> {
    let frames = p.get_or("frames", 8usize).map_err(bad)?;
    let workers = p.get_or("workers", 4usize).map_err(bad)?;
    let params = TrackerParams {
        n_particles: p.get_or("particles", 32usize).map_err(bad)?,
        sigma: p.get_or("sigma", 3.0f64).map_err(bad)?,
        roi_r: p.get_or("roi", 5i32).map_err(bad)?,
        seed: p.get_or("seed", 7u64).map_err(bad)?,
    };
    let video = synthetic_video(64, 48, frames, 6, p.get_or("vseed", 11u64).map_err(bad)?);
    let tracker = PfilterNocTracker::on_mesh(workers, params);
    println!(
        "particle filter over NoC: {frames} frames, {} particles, {workers} workers",
        params.n_particles
    );
    let run = tracker.track(&video, video.truth[0], None);
    for (k, (&est, &truth)) in run.centers.iter().zip(&video.truth).enumerate() {
        println!("  frame {k:2}: est {est:?} truth {truth:?}");
    }
    println!("  cycles={} flits={}", run.report.cycles, run.report.net.delivered);
    Ok(())
}

fn cmd_bmvm(p: &Parsed) -> Result<(), String> {
    let n = p.get_or("n", 1024usize).map_err(bad)?;
    let k = p.get_or("k", 4usize).map_err(bad)?;
    let pes = p.get_or("pes", 64usize).map_err(bad)?;
    let r = p.get_or("r", 10u32).map_err(bad)?;
    let topo = p.raw("topo").unwrap_or("mesh").to_string();
    let mut rng = Rng::new(p.get_or("seed", 3u64).map_err(bad)?);
    let a = Gf2Matrix::random(n, n, &mut rng);
    let luts = WilliamsLuts::preprocess(&a, k);
    let v = BitVec::random(n, &mut rng);
    let sys = BmvmSystem::new(luts, pes, BmvmSystem::topology_for(&topo, pes));
    println!(
        "BMVM n={n} k={k} f={} PEs={pes} topo={topo} r={r} (LUTs {:.2} Mb BRAM)",
        sys.fold(),
        sys.bram_bits() as f64 / (1024.0 * 1024.0)
    );
    let run = sys.run(&v, r, None);
    assert_eq!(run.result, dense_power_matvec(&a, &v, r), "verify vs dense oracle");
    println!(
        "  cycles={} time={:.3} ms (incl. host link) flits={} — verified vs dense A^r v",
        run.report.cycles, run.time_ms, run.report.net.delivered
    );
    Ok(())
}

const DFG_SAMPLE: &str = "input a;\ninput b;\nt0 = a + b;\nt1 = a * 7;\nt2 = t0 ^ t1;\nt3 = t2 min b;\nt4 = t3 << 2;\ny = t4 - a;\noutput y;\n";

fn cmd_dfg(p: &Parsed) -> Result<(), String> {
    let cores = p.get_or("cores", 2usize).map_err(bad)?;
    let src = match p.raw("file") {
        Some(f) => std::fs::read_to_string(f).map_err(|e| format!("read {f}: {e}"))?,
        None => DFG_SAMPLE.to_string(),
    };
    let g = dfg::parse(&src).map_err(|e| format!("parse program: {e}"))?;
    let prog = mips::compile(&g, cores);
    println!("; DFG: {} nodes, {} outputs, {} cores", g.nodes.len(), g.outputs.len(), cores);
    print!("{}", prog.listing());
    let a_args: Vec<u32> = (0..g.inputs.len()).map(|i| 10 + 3 * i as u32).collect();
    let run = mips::run(&prog, &g, &a_args, 1_000_000);
    println!("; inputs {a_args:?} -> outputs {:?} (oracle {:?})", run.outputs, g.eval(&a_args));
    println!("; {} cycles, blocked/core {:?}", run.cycles, run.blocked);
    assert_eq!(run.outputs, g.eval(&a_args));
    Ok(())
}

fn cmd_noc(p: &Parsed) -> Result<(), String> {
    let eps = p.get_or("endpoints", 16usize).map_err(bad)?;
    let topo = topo_from_name(p.raw("topo").unwrap_or("mesh4x4"), eps)?;
    let flits = p.get_or("flits", 5000u32).map_err(bad)?;
    let mut net = Network::new(&topo, NocConfig::paper());
    let n = net.n_endpoints();
    let mut rng = Rng::new(p.get_or("seed", 1u64).map_err(bad)?);
    for i in 0..flits {
        let s = rng.index(n);
        let d = (s + 1 + rng.index(n - 1)) % n;
        net.inject(s, Flit::single(s, d, i, i as u64));
    }
    let cycles = net.run_until_idle(100_000_000).expect("network stalled");
    println!("{topo:?}: {} endpoints, {flits} flits uniform-random", n);
    println!("  drained in {cycles} cycles — {}", net.stats());
    let g = net.topo();
    println!("  avg hops {:.2}, diameter {}", g.avg_hops(), g.diameter());
    Ok(())
}

fn cmd_scenarios(p: &Parsed) -> Result<(), String> {
    let eps = p.get_or("endpoints", 64usize).map_err(bad)?;
    let topo = topo_from_name(p.raw("topo").unwrap_or("mesh8x8"), eps)?;
    let engine = engine_from_name(p.raw("engine").unwrap_or("event"))?;
    let load = p.get_or("load", 0.05f64).map_err(bad)?;
    let cycles = p.get_or("cycles", 2_000u64).map_err(bad)?;
    let seed = p.get_or("seed", 1u64).map_err(bad)?;
    let which = p.raw("scenario").unwrap_or("all").to_string();
    // --chips N (N >= 2) runs the sharded multi-FPGA co-simulation:
    // Partition::balanced over N chips, cut links on quasi-serdes wires.
    let chips = p.get_or("chips", 0usize).map_err(bad)?;
    let cfg = NocConfig { engine, ..NocConfig::paper() };
    let partition = (chips >= 2).then(|| Partition::balanced(&topo.build(), chips, seed));
    let serdes = SerdesConfig {
        pins: p.get_or("pins", 8u32).map_err(bad)?,
        clock_div: p.get_or("clock-div", 1u32).map_err(bad)?,
        tx_buffer: 8,
    };
    println!(
        "scenario matrix on {topo:?} — {} engine, load {load}, {cycles}-cycle window, seed {seed}{}",
        engine.name(),
        if chips >= 2 {
            format!(", sharded across {chips} FPGAs ({} pins)", serdes.pins)
        } else {
            String::new()
        }
    );
    let mut matched = false;
    for scn in scenario::registry() {
        if which != "all" && scn.name != which {
            continue;
        }
        matched = true;
        // Both arms surface failures as MultiChipError: the monolithic
        // run can only stall, the sharded one can also hit an
        // unreconstructable frame on an unprotected faulty wire.
        let outcome = match &partition {
            Some(part) => {
                let sharding = scenario::Sharding { partition: part, serdes };
                scenario::run_scenario_multichip(&scn, &topo, cfg, &sharding, load, cycles, seed)
            }
            None => scenario::run_scenario(&scn, &topo, cfg, load, cycles, seed)
                .map_err(fabricflow::noc::MultiChipError::from),
        };
        match outcome {
            Ok(out) => {
                println!("  {:14} {}", scn.name, out.report);
                if let Some(busiest) =
                    out.report.links.iter().max_by_key(|l| l.active_cycles)
                {
                    println!(
                        "  {:14}   busiest link R{}→R{}: {} flits, {:.1}% occupied, {} stall cyc",
                        "",
                        busiest.from.0,
                        busiest.to.0,
                        busiest.carried,
                        100.0 * busiest.occupancy(out.report.net.cycles),
                        busiest.stall_cycles
                    );
                }
            }
            Err(e) => println!("  {:14} FAILED: {e}", scn.name),
        }
    }
    if !matched {
        return Err(format!(
            "unknown scenario '{which}' (one of: {}, all)",
            scenario::registry().iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
        ));
    }
    Ok(())
}

fn cmd_trace(p: &Parsed) -> Result<(), String> {
    use fabricflow::noc::multichip::MultiChipSim;
    use fabricflow::noc::trace::{attribute, link_loads};
    let eps = p.get_or("endpoints", 64usize).map_err(bad)?;
    let topo = topo_from_name(p.raw("topo").unwrap_or("mesh8x8"), eps)?;
    let engine = engine_from_name(p.raw("engine").unwrap_or("event"))?;
    let which = p.raw("scenario").unwrap_or("hotspot");
    let scn = scenario::by_name(which).ok_or_else(|| {
        format!(
            "unknown scenario '{which}' (one of: {})",
            scenario::registry().iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
        )
    })?;
    let load = p.get_or("load", 0.1f64).map_err(bad)?;
    let window = p.get_or("cycles", 2_000u64).map_err(bad)?;
    let seed = p.get_or("seed", 1u64).map_err(bad)?;
    let capacity = p.get_or("capacity", 1usize << 16).map_err(bad)?;
    let top = p.get_or("top", 8usize).map_err(bad)?;
    let chips = p.get_or("chips", 0usize).map_err(bad)?;
    let cfg = NocConfig { engine, ..NocConfig::paper() };
    let graph = topo.build();
    let inj = scn.trace(graph.n_endpoints, load, window, seed);

    // Run traced, then pull the event stream and the exact channel
    // profile out of the recorder(s).
    let (done, stats, events, (recorded, dropped), profile) = if chips >= 2 {
        let partition = Partition::balanced(&graph, chips, seed);
        let serdes = SerdesConfig {
            pins: p.get_or("pins", 8u32).map_err(bad)?,
            clock_div: p.get_or("clock-div", 1u32).map_err(bad)?,
            tx_buffer: 8,
        };
        let mut sim = MultiChipSim::from_graph(graph.clone(), cfg, &partition, serdes);
        sim.enable_trace(capacity);
        let done = scenario::replay_multichip(&mut sim, &inj, 1_000_000_000)
            .map_err(|e| format!("replay: {e}"))?;
        (done, sim.stats(), sim.trace_events(), sim.trace_counts(), sim.channel_profile())
    } else {
        let mut net = Network::new(&topo, cfg);
        net.enable_trace(capacity);
        let done = scenario::replay(&mut net, &inj, 100_000_000)
            .map_err(|e| format!("replay: {e}"))?;
        let tb = net.trace().expect("recorder enabled");
        let counts = (tb.recorded(), tb.dropped());
        (done, net.stats().clone(), tb.events(), counts, net.channel_profile())
    };
    let attr = attribute(&events);
    // Heaviest physical links and logical channels, by measured
    // flit-hops, descending (ties broken by key for determinism).
    let mut links: Vec<((u16, u32, u16), u64)> = link_loads(&events).into_iter().collect();
    links.sort_by_key(|&(key, n)| (std::cmp::Reverse(n), key));
    links.truncate(top);
    let mut channels: Vec<((u32, u32), u64)> = profile.iter().collect();
    channels.sort_by_key(|&(key, n)| (std::cmp::Reverse(n), key));
    channels.truncate(top);

    if p.has("json") {
        use std::fmt::Write as _;
        let mut j = String::new();
        let _ = writeln!(j, "{{");
        let _ = writeln!(j, "  \"schema\": \"fabricflow-trace/v1\",");
        let _ = writeln!(j, "  \"scenario\": \"{}\",", scn.name);
        let _ = writeln!(j, "  \"topo\": \"{topo:?}\",");
        let _ = writeln!(j, "  \"engine\": \"{}\",", engine.name());
        let _ = writeln!(j, "  \"load\": {load},");
        let _ = writeln!(j, "  \"window\": {window},");
        let _ = writeln!(j, "  \"seed\": {seed},");
        let _ = writeln!(j, "  \"chips\": {chips},");
        let _ = writeln!(j, "  \"cycles\": {done},");
        let _ = writeln!(j, "  \"delivered\": {},", stats.delivered);
        let _ = writeln!(j, "  \"capacity\": {capacity},");
        let _ = writeln!(j, "  \"recorded\": {recorded},");
        let _ = writeln!(j, "  \"dropped\": {dropped},");
        let _ = writeln!(j, "  \"attribution\": {{");
        let _ = writeln!(j, "    \"flits\": {},", attr.flits.len());
        let _ = writeln!(j, "    \"avg_latency\": {:.2},", attr.avg_latency());
        let _ = writeln!(j, "    \"total_latency\": {},", attr.total_latency);
        let _ = writeln!(j, "    \"total_queueing\": {},", attr.total_queueing);
        let _ = writeln!(j, "    \"total_hops\": {},", attr.total_hops);
        let _ = writeln!(j, "    \"total_wire\": {}", attr.total_wire);
        let _ = writeln!(j, "  }},");
        let _ = writeln!(j, "  \"links\": [");
        for (i, &((chip, router, port), n)) in links.iter().enumerate() {
            let comma = if i + 1 == links.len() { "" } else { "," };
            let _ = writeln!(
                j,
                "    {{\"chip\": {chip}, \"router\": {router}, \"port\": {port}, \"flit_hops\": {n}}}{comma}"
            );
        }
        let _ = writeln!(j, "  ],");
        let _ = writeln!(j, "  \"channels\": [");
        for (i, &((src, dst), n)) in channels.iter().enumerate() {
            let comma = if i + 1 == channels.len() { "" } else { "," };
            let _ = writeln!(
                j,
                "    {{\"src\": {src}, \"dst\": {dst}, \"flit_hops\": {n}}}{comma}"
            );
        }
        let _ = writeln!(j, "  ]");
        let _ = writeln!(j, "}}");
        print!("{j}");
        return Ok(());
    }

    println!(
        "flit trace: {} on {topo:?} — {} engine, load {load}, {window}-cycle window, seed {seed}{}",
        scn.name,
        engine.name(),
        if chips >= 2 { format!(", sharded across {chips} FPGAs") } else { String::new() }
    );
    println!("  drained in {done} cycles — {stats}");
    println!(
        "  recorded {recorded} events ({dropped} overwritten by ring wrap, capacity {capacity})"
    );
    let n_attr = attr.flits.len().max(1) as u64;
    println!(
        "  latency split over {} attributed flits: avg {:.1} cyc = {:.1} queueing + {:.1} hops + {:.1} wire",
        attr.flits.len(),
        attr.avg_latency(),
        attr.total_queueing as f64 / n_attr as f64,
        attr.total_hops as f64 / n_attr as f64,
        attr.total_wire as f64 / n_attr as f64
    );
    println!("  top links by flit-hops (surviving events):");
    for &((chip, router, port), n) in &links {
        println!("    chip{chip} R{router}.p{port:<3} {n:>8}");
    }
    println!("  top channels by measured flit-hops (exact):");
    for &((src, dst), n) in &channels {
        println!("    ep{src:<4} -> ep{dst:<4} {n:>8}");
    }
    Ok(())
}

fn cmd_sweep(p: &Parsed) -> Result<(), String> {
    use std::time::Instant;
    let eps = p.get_or("endpoints", 64usize).map_err(bad)?;
    let topo = topo_from_name(p.raw("topo").unwrap_or("mesh8x8"), eps)?;
    let engine = engine_from_name(p.raw("engine").unwrap_or("event"))?;
    let threads = p.get_or("threads", fabricflow::fleet::default_threads()).map_err(bad)?;
    let cycles = p.get_or("cycles", 800u64).map_err(bad)?;
    // Axes go through the strict parser: empty elements and duplicate
    // values are typed errors (duplicates would silently enqueue
    // redundant jobs and inflate jobs/sec).
    let loads: Vec<f64> =
        p.get_axis("loads").map_err(bad)?.unwrap_or_else(|| vec![0.02, 0.1]);
    // --seeds N sweeps seeds 1..=N; --lanes L expands each into L
    // Monte-Carlo lanes (seed + L-1 splitmix64 follow-ons).
    let seeds: Vec<u64> = (1..=p.get_or("seeds", 4u64).map_err(bad)?).collect();
    let lanes = p.get_or("lanes", 1usize).map_err(bad)?;
    let which = p.raw("scenario").unwrap_or("all").to_string();
    let scenarios: Vec<scenario::Scenario> = scenario::registry()
        .into_iter()
        .filter(|s| which == "all" || s.name == which)
        .collect();
    if scenarios.is_empty() {
        return Err(format!("unknown scenario '{which}'"));
    }
    let cfg = NocConfig { engine, ..NocConfig::paper() };
    let grid =
        scenario::SweepGrid { topo: topo.clone(), cfg, scenarios, loads, seeds, cycles, lanes };
    let chips = p.get_or("chips", 0usize).map_err(bad)?;
    let t = Instant::now();
    // (cells for the per-cell printout, merged stats for the aggregate)
    let (n_jobs, rows, mut agg) = if chips >= 2 {
        let partition =
            Partition::balanced(&topo.build(), chips, p.get_or("seed", 1u64).map_err(bad)?);
        let pins: Vec<u32> = p.get_axis("pins").map_err(bad)?.unwrap_or_else(|| vec![8]);
        let divs: Vec<u32> =
            p.get_axis("clock-divs").map_err(bad)?.unwrap_or_else(|| vec![1]);
        let mut serdes_points = Vec::new();
        for &pin in &pins {
            for &d in &divs {
                serdes_points.push(SerdesConfig { pins: pin, clock_div: d, tx_buffer: 8 });
            }
        }
        // --fault-rates adds a degraded-wire axis: each nonzero rate
        // seeds bit flips AND flit drops at that probability, recovered
        // by CRC/retransmit (rate 0 = clean wires, no CRC overhead).
        let rates: Vec<f64> =
            p.get_axis("fault-rates").map_err(bad)?.unwrap_or_else(|| vec![0.0]);
        let cells = scenario::run_multichip_grid_faulty(
            &grid,
            &partition,
            &serdes_points,
            &rates,
            threads,
        )
        .map_err(|e| format!("multichip sweep failed: {e}"))?;
        let mut agg = fabricflow::noc::NetStats::default();
        let rows: Vec<String> = cells
            .iter()
            .map(|c| {
                agg.merge(&c.stats);
                format!(
                    "{:12} load {:<5} seed {:<3} {:>2} pins /{} div fault {:<6} {:>8} cyc {:>7} flits {:>6} wire {:>5} retrans | p50 {} p95 {} p99 {}",
                    c.scenario, c.load, c.seed, c.pins, c.clock_div, c.fault_rate, c.cycles,
                    c.stats.delivered, c.wire_flits, c.retransmits,
                    c.stats.p50(), c.stats.p95(), c.stats.p99()
                )
            })
            .collect();
        (cells.len(), rows, agg)
    } else {
        let cells = scenario::run_grid(&grid, threads)
            .map_err(|e| format!("sweep stalled: {e}"))?;
        let mut agg = fabricflow::noc::NetStats::default();
        let rows: Vec<String> = cells
            .iter()
            .map(|c| {
                agg.merge(&c.stats);
                format!(
                    "{:12} load {:<5} seed {:<3} {:>8} cyc {:>7} flits | p50 {} p95 {} p99 {}",
                    c.scenario, c.load, c.seed, c.cycles, c.stats.delivered,
                    c.stats.p50(), c.stats.p95(), c.stats.p99()
                )
            })
            .collect();
        (cells.len(), rows, agg)
    };
    let wall = t.elapsed().as_secs_f64();
    println!(
        "fleet sweep on {topo:?} — {} engine, {n_jobs} jobs, {threads} thread(s){}",
        engine.name(),
        if chips >= 2 { format!(", {chips} FPGAs") } else { String::new() }
    );
    for row in rows {
        println!("  {row}");
    }
    agg.cycles = 0; // per-job clocks are independent; don't fake a fabric clock
    println!(
        "  aggregate: {} injected, {} delivered, avg lat {:.1}, p50 {} p95 {} p99 {}",
        agg.injected,
        agg.delivered,
        agg.avg_latency(),
        agg.p50(),
        agg.p95(),
        agg.p99()
    );
    println!("  {n_jobs} jobs in {:.1} ms — {:.1} jobs/sec", wall * 1e3, n_jobs as f64 / wall);
    Ok(())
}

fn cmd_optimize(p: &Parsed) -> Result<(), String> {
    use fabricflow::optimize::{self, OptimizeSetup};
    use fabricflow::space::{SearchSpace, TopoSpec};
    use std::time::Instant;

    let chips = p.get_or("chips", 1usize).map_err(bad)?;
    // Every axis goes through the strict parser — empty and duplicate
    // values are typed errors, not silent no-ops.
    let topo_names: Vec<String> = p.get_axis("topos").map_err(bad)?.unwrap_or_else(|| {
        vec!["mesh2x2".to_string(), "mesh3x3".to_string(), "mesh4x4".to_string()]
    });
    let topos = topo_names
        .iter()
        .map(|s| TopoSpec::decode(s))
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| e.to_string())?;
    let pins: Vec<u32> = p
        .get_axis("pins")
        .map_err(bad)?
        .unwrap_or_else(|| if chips >= 2 { vec![2, 8] } else { vec![8] });
    let clock_divs: Vec<u32> =
        p.get_axis("clock-divs").map_err(bad)?.unwrap_or_else(|| vec![1]);
    let buffer_depths: Vec<usize> =
        p.get_axis("depths").map_err(bad)?.unwrap_or_else(|| vec![4, 8]);
    let part_seeds: Vec<u64> =
        p.get_axis("part-seeds").map_err(bad)?.unwrap_or_else(|| vec![1]);
    let engine = engine_from_name(p.raw("engine").unwrap_or("event"))?;
    let scn_name = p.raw("scenario").unwrap_or("uniform");
    let scn =
        scenario::find(scn_name).ok_or_else(|| format!("unknown scenario '{scn_name}'"))?;
    let load = p.get_or("load", 0.1f64).map_err(bad)?;
    let window = p.get_or("cycles", 400u64).map_err(bad)?;

    let space =
        SearchSpace { topos, pins, clock_divs, buffer_depths, part_seeds, chips, pinned: vec![] };
    let mut setup = OptimizeSetup::new(space, scn, load, window);
    setup.seed = p.get_or("seed", 1u64).map_err(bad)?;
    setup.base = NocConfig { engine, ..NocConfig::paper() };
    setup.threads =
        p.get_or("threads", fabricflow::fleet::default_threads()).map_err(bad)?;
    setup.probe_budget = p.get_or("probe", setup.probe_budget).map_err(bad)?;
    setup.full_budget = p.get_or("budget", setup.full_budget).map_err(bad)?;

    let exhaustive = p.has("exhaustive");
    let t = Instant::now();
    let report = if exhaustive { optimize::exhaustive(&setup) } else { optimize::race(&setup) }
        .map_err(|e| format!("optimize failed: {e}"))?;
    let search_ms = t.elapsed().as_secs_f64() * 1e3;
    let best = *report.best().expect("non-empty front");

    // Anneal the winner's partition with the simulator in the loop,
    // warm-started from the bisection placer.
    let sweeps = p.get_or("sweeps", 1usize).map_err(bad)?;
    let sa_iters = p.get_or("sa-iters", 16usize).map_err(bad)?;
    let refined = if best.point.chips >= 2 && sweeps + sa_iters > 0 {
        let graph = best.point.topo.build_topology().build();
        let start = best
            .point
            .partition(&graph, &[])
            .map_err(|e| e.to_string())?
            .expect("multichip point has a partition");
        let trace = scn.trace(graph.n_endpoints, load, window, setup.seed);
        let mut eval = |part: &Partition| {
            optimize::partition_cycles(
                &graph,
                &best.point,
                &setup.base,
                part,
                &trace,
                setup.full_budget,
            )
        };
        Some(optimize::refine_partition(
            &graph, &start, &[], sweeps, sa_iters, setup.seed, &mut eval,
        ))
    } else {
        None
    };
    let wall_ms = t.elapsed().as_secs_f64() * 1e3;

    if p.has("json") {
        let front: Vec<String> = report
            .front
            .iter()
            .map(|e| {
                format!(
                    "{{\"point\": {}, \"cycles\": {}, \"luts\": {}, \"regs\": {}, \"bram_bits\": {}, \"wire_pins\": {}}}",
                    e.point.to_json(),
                    e.cycles,
                    e.est.per_fpga.luts,
                    e.est.per_fpga.regs,
                    e.est.per_fpga.bram_bits,
                    e.est.wire_pins
                )
            })
            .collect();
        let refined_json = match &refined {
            Some(r) => format!(
                "{{\"assignment\": {:?}, \"cycles\": {}, \"start_cycles\": {}, \"evals\": {}}}",
                r.partition.assignment, r.cycles, r.start_cycles, r.evals
            ),
            None => "null".to_string(),
        };
        println!(
            "{{\n  \"scenario\": \"{}\",\n  \"mode\": \"{}\",\n  \"space_points\": {},\n  \"finished\": {},\n  \"infeasible\": {},\n  \"probe_runs\": {},\n  \"full_runs\": {},\n  \"pruned\": {},\n  \"front\": [{}],\n  \"winner\": {},\n  \"refined\": {},\n  \"wall_ms\": {:.1}\n}}",
            scn.name,
            if exhaustive { "exhaustive" } else { "racing" },
            report.space_points,
            report.finished,
            report.infeasible,
            report.probe_runs,
            report.full_runs,
            report.pruned,
            front.join(", "),
            best.point.to_json(),
            refined_json,
            wall_ms
        );
        return Ok(());
    }

    println!(
        "design-space autopilot — scenario '{}', load {load}, window {window} cyc, {} points, {} search, {} thread(s)",
        scn.name,
        report.space_points,
        if exhaustive { "exhaustive" } else { "racing" },
    );
    println!("  Pareto front ({} point(s)):", report.front.len());
    for e in &report.front {
        println!(
            "    {:24} {:>8} cyc  {:>6} luts {:>6} regs {:>7} bram_bits  {:>4} wire pins",
            e.point.encode(),
            e.cycles,
            e.est.per_fpga.luts,
            e.est.per_fpga.regs,
            e.est.per_fpga.bram_bits,
            e.est.wire_pins
        );
    }
    println!(
        "  {} finished, {} infeasible | {} probe + {} full runs, {} pruned | search {:.1} ms",
        report.finished,
        report.infeasible,
        report.probe_runs,
        report.full_runs,
        report.pruned,
        search_ms
    );
    if let Some(r) = &refined {
        if r.improved {
            println!(
                "  annealed partition: cycles {} -> {} over {} eval(s), assignment {:?}",
                r.start_cycles, r.cycles, r.evals, r.partition.assignment
            );
        } else {
            println!(
                "  annealed partition: warm start already optimal ({} cyc, {} eval(s))",
                r.start_cycles, r.evals
            );
        }
    }
    println!("  winner (JSON): {}", best.point.to_json());
    println!("  winner (FlowBuilder):");
    for line in best.point.builder_code(&setup.base).lines() {
        println!("    {line}");
    }
    Ok(())
}

fn cmd_bench(p: &Parsed) -> Result<(), String> {
    let quick = p.has("quick");
    let out = p.raw("out").unwrap_or("BENCH_noc.json").to_string();
    let sel = match p.raw("only") {
        Some(s) => fabricflow::perf::BenchSelect::parse(s).ok_or_else(|| {
            format!(
                "bad --only '{s}' (comma-separated: points, multichip, sweep, serve, faults, bitsliced, trace, optimize)"
            )
        })?,
        None => fabricflow::perf::BenchSelect::ALL,
    };
    let report = fabricflow::perf::run_selected(quick, sel);
    // Table on stderr so `--out -` leaves stdout as pure, parseable JSON.
    eprint!("{}", report.render_table());
    // --only + an existing file: read-modify-write, preserving the
    // sections this run did not regenerate.
    let json = if sel.is_all() || out == "-" {
        report.to_json()
    } else {
        match std::fs::read_to_string(&out) {
            Ok(old) => fabricflow::perf::merge_sections(&old, &report, sel),
            Err(_) => report.to_json(),
        }
    };
    if out == "-" {
        print!("{json}");
    } else {
        std::fs::write(&out, &json).map_err(|e| format!("write {out}: {e}"))?;
        println!("wrote {out}");
    }
    Ok(())
}

fn serve_config(p: &Parsed) -> Result<serve::ServeConfig, String> {
    let mut cfg = serve::ServeConfig::default();
    cfg.threads = p.get_or("threads", cfg.threads).map_err(bad)?;
    cfg.queue_cap = p.get_or("queue", cfg.queue_cap).map_err(bad)?;
    if let Some(a) = p.raw("admission") {
        cfg.admission = serve::Admission::parse(a)
            .ok_or_else(|| format!("unknown admission '{a}' (block, reject)"))?;
    }
    if let Some(t) = p.raw("topo") {
        cfg.topo = topo_from_name(t, p.get_or("endpoints", 16usize).map_err(bad)?)?;
    }
    cfg.bmvm.n = p.get_or("bmvm-n", cfg.bmvm.n).map_err(bad)?;
    cfg.bmvm.k = p.get_or("bmvm-k", cfg.bmvm.k).map_err(bad)?;
    cfg.bmvm.pes = p.get_or("bmvm-pes", cfg.bmvm.pes).map_err(bad)?;
    if let Some(t) = p.raw("bmvm-topo") {
        cfg.bmvm.topo = t.to_string();
    }
    cfg.bmvm.seed = p.get_or("bmvm-seed", cfg.bmvm.seed).map_err(bad)?;
    cfg.bmvm.validate()?;
    Ok(cfg)
}

fn cmd_serve(p: &Parsed) -> Result<(), String> {
    let cfg = serve_config(p)?;
    // Frames go to stdout; everything human-readable goes to stderr so
    // `loadgen | serve > responses.bin` stays clean.
    eprintln!(
        "serve: {} warm replicas on {:?}, queue {} ({:?} admission)",
        cfg.threads, cfg.topo, cfg.queue_cap, cfg.admission
    );
    let summary = match p.raw("uds") {
        Some(path) => {
            // Unix-socket mode: accept ONE connection and serve it to
            // EOF (the open-loop client closes its write half when the
            // stream ends).
            let _ = std::fs::remove_file(path);
            let listener = std::os::unix::net::UnixListener::bind(path)
                .map_err(|e| format!("bind {path}: {e}"))?;
            eprintln!("serve: listening on {path}");
            let (sock, _) = listener.accept().map_err(|e| format!("accept: {e}"))?;
            let reader = sock.try_clone().map_err(|e| format!("clone socket: {e}"))?;
            let summary = serve::serve_stream(&cfg, reader, sock)
                .map_err(|e| format!("serve: {e}"))?;
            let _ = std::fs::remove_file(path);
            summary
        }
        None => serve::serve_stream(&cfg, std::io::stdin().lock(), std::io::stdout().lock())
            .map_err(|e| format!("serve: {e}"))?,
    };
    eprintln!("{}", summary.render());
    if p.has("fail-on-reject") && summary.rejected > 0 {
        return Err(format!(
            "{} requests rejected below the declared saturation point",
            summary.rejected
        ));
    }
    Ok(())
}

fn cmd_loadgen(p: &Parsed) -> Result<(), String> {
    let mut cfg = loadgen::LoadgenConfig::default();
    cfg.requests = p.get_or("requests", cfg.requests).map_err(bad)?;
    cfg.rate = p.get_or("rate", cfg.rate).map_err(bad)?;
    cfg.seed = p.get_or("seed", cfg.seed).map_err(bad)?;
    if let Some(mix) = p.raw("mix") {
        let mut kinds = Vec::new();
        for part in mix.split(',').filter(|s| !s.is_empty()) {
            kinds.push(loadgen::ReqKind::parse(part).ok_or_else(|| {
                format!("unknown mix kind '{part}' (scenario, ldpc, pfilter, bmvm)")
            })?);
        }
        if kinds.is_empty() {
            return Err("--mix must name at least one kind".to_string());
        }
        cfg.mix = kinds;
    }
    match p.raw("arrivals").unwrap_or("poisson") {
        "poisson" => cfg.arrivals = loadgen::ArrivalModel::Poisson,
        "bursty" => {
            cfg.arrivals = loadgen::ArrivalModel::Bursty {
                on_ms: p.get_or("on-ms", 10u64).map_err(bad)?,
                off_ms: p.get_or("off-ms", 30u64).map_err(bad)?,
            }
        }
        other => return Err(format!("unknown arrivals '{other}' (poisson, bursty)")),
    }
    cfg.bmvm.n = p.get_or("bmvm-n", cfg.bmvm.n).map_err(bad)?;
    let pace = !p.has("max-speed");
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let offered_s = loadgen::write_stream(&cfg, &mut out, pace)
        .map_err(|e| format!("loadgen: {e}"))?;
    eprintln!(
        "loadgen: {} requests, seed {}, {} — offered span {:.3}s",
        cfg.requests,
        cfg.seed,
        if cfg.rate > 0.0 {
            format!("{:.0} req/s {:?}", cfg.rate, cfg.arrivals)
        } else {
            "flood".to_string()
        },
        offered_s
    );
    Ok(())
}

fn cmd_resources(_p: &Parsed) -> Result<(), String> {
    for d in [Device::ZC7020, Device::VIRTEX6_ML605, Device::DE0_NANO] {
        println!(
            "{:28} {:>7} FF {:>7} LUT {:>4} DSP {:>6} Kb BRAM",
            d.name,
            d.regs,
            d.luts,
            d.dsp,
            d.bram_bits / 1024
        );
    }
    println!();
    print!("{}", tables::table1());
    Ok(())
}

fn cmd_partition_demo(p: &Parsed) -> Result<(), String> {
    // Fig 5: 4-router custom NoC, R0 on its own FPGA.
    let topo = Topology::Custom {
        n_routers: 4,
        links: vec![(0, 1), (1, 2), (2, 3), (3, 0)],
        endpoint_router: vec![0, 1, 2, 3],
    };
    let part = Partition::island(4, &[0]);
    let serdes = SerdesConfig {
        pins: p.get_or("pins", 8u32).map_err(bad)?,
        clock_div: p.get_or("clock-div", 1u32).map_err(bad)?,
        tx_buffer: 8,
    };
    let g = topo.build();
    println!("Fig 5 demo: 4-router NoC, R0+N0 on FPGA 1, rest on FPGA 0");
    println!("  cut links: {:?}", part.cut_links(&g));
    println!("  pins/FPGA: {:?}", part.pins_per_fpga(&g, &serdes));
    let mut net = Network::new(&topo, NocConfig::paper());
    part.apply(&mut net, serdes);
    let mut rng = Rng::new(9);
    for i in 0..2000u32 {
        let s = rng.index(4);
        let d = (s + 1 + rng.index(3)) % 4;
        net.inject(s, Flit::single(s, d, i, i as u64));
    }
    let cycles = net.run_until_idle(10_000_000).expect("network stalled");
    println!("  2000 flits drained in {cycles} cycles — {}", net.stats());
    for ((r, port), ch) in net.serdes_channels() {
        println!(
            "  serdes at R{r}.p{port}: {} flits carried, {} cycles/flit",
            ch.carried, ch.ser_cycles
        );
    }
    Ok(())
}

fn usage_banner() -> String {
    let names: Vec<&str> = COMMANDS.iter().map(|c| c.name).collect();
    format!("usage: fabricflow <{}> [flags]", names.join("|"))
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd_name) = argv.first().cloned() else {
        eprintln!("{}", usage_banner());
        std::process::exit(2);
    };
    let Some(cmd) = COMMANDS.iter().find(|c| c.name == cmd_name) else {
        eprintln!("unknown command '{cmd_name}'");
        eprintln!("{}", usage_banner());
        std::process::exit(2);
    };
    let parsed = match args::parse(cmd.spec, &argv[1..]) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("fabricflow {}: {e}", cmd.name);
            eprintln!("usage: fabricflow {}", cmd.usage);
            std::process::exit(2);
        }
    };
    if let Err(e) = (cmd.run)(&parsed) {
        eprintln!("fabricflow {}: {e}", cmd.name);
        std::process::exit(1);
    }
}
