//! fabricflow — command-line launcher for the framework.
//!
//! ```text
//! fabricflow tables --id all            # regenerate paper Tables I–V
//! fabricflow ldpc --niter 10 --flip 3   # Fig 9 decode over the NoC
//! fabricflow track --frames 8           # Fig 10 tracking over the NoC
//! fabricflow bmvm --topo torus --r 100  # §VI BMVM on a topology
//! fabricflow dfg --cores 4              # Fig 2 DFG→MIPS flow
//! fabricflow noc --topo mesh8x8         # raw NoC traffic experiment
//! fabricflow scenarios --topo mesh8x8   # scenario matrix (engine-selectable)
//! fabricflow scenarios --chips 2        # …sharded across FPGAs (multichip co-sim)
//! fabricflow sweep --threads 8          # fleet: scenario × load × seed grid
//! fabricflow sweep --chips 2 --pins 1,8 # …multichip grid across wire configs
//! fabricflow bench --out BENCH_noc.json # tracked NoC benchmark matrix
//! fabricflow bench --only sweep         # …regenerate one section, keep the rest
//! fabricflow partition                  # Fig 5 quasi-SERDES demo
//! fabricflow resources                  # device + component inventory
//! ```
//!
//! (clap is unavailable in the offline container; flags are parsed by the
//! small [`Args`] helper.)

use std::collections::HashMap;

use fabricflow::apps::bmvm::{dense_power_matvec, BmvmSystem, WilliamsLuts};
use fabricflow::apps::ldpc::mapper::LdpcNocDecoder;
use fabricflow::apps::ldpc::minsum::{codeword_llrs, MinsumVariant};
use fabricflow::apps::pfilter::{synthetic_video, PfilterNocTracker, TrackerParams};
use fabricflow::gf2::Gf2Matrix;
use fabricflow::noc::{scenario, Flit, Network, NocConfig, SimEngine, Topology};
use fabricflow::resources::Device;
use fabricflow::serdes::SerdesConfig;
use fabricflow::tables::{self, TableOpts};
use fabricflow::util::bits::BitVec;
use fabricflow::util::Rng;
use fabricflow::{dfg, mips, partition::Partition};

/// Minimal `--flag value` / `--switch` parser.
struct Args {
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut switches = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    switches.push(name.to_string());
                    i += 1;
                }
            } else {
                switches.push(a.clone());
                i += 1;
            }
        }
        Args { flags, switches }
    }

    fn get<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        self.flags
            .get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

fn topo_from_name(name: &str, endpoints: usize) -> Topology {
    match name {
        "ring" => Topology::Ring(endpoints),
        "mesh" | "torus" => {
            let side = (endpoints as f64).sqrt().ceil() as usize;
            if name == "mesh" {
                Topology::Mesh { w: side, h: endpoints.div_ceil(side) }
            } else {
                Topology::Torus { w: side, h: endpoints.div_ceil(side) }
            }
        }
        "fat_tree" => Topology::fat_tree(endpoints),
        other => {
            // meshWxH / torusWxH
            for (prefix, is_torus) in [("mesh", false), ("torus", true)] {
                if let Some(dims) = other.strip_prefix(prefix) {
                    if let Some((w, h)) = dims.split_once('x') {
                        let (w, h) = (w.parse().unwrap(), h.parse().unwrap());
                        return if is_torus {
                            Topology::Torus { w, h }
                        } else {
                            Topology::Mesh { w, h }
                        };
                    }
                }
            }
            panic!("unknown topology '{other}'");
        }
    }
}

fn cmd_tables(args: &Args) {
    let opts = TableOpts {
        reps: args.get("reps", 3usize),
        quick: args.has("quick"),
        seed: args.get("seed", 0x7AB1Eu64),
    };
    match args.str("id", "all").as_str() {
        "t1" => print!("{}", tables::table1()),
        "t2" => print!("{}", tables::table2()),
        "t3" => print!("{}", tables::table3()),
        "t4" => print!("{}", tables::table4(&opts)),
        "t5" => print!("{}", tables::table5(&opts)),
        "all" => print!("{}", tables::all_tables(&opts)),
        other => eprintln!("unknown table id '{other}' (t1..t5, all)"),
    }
}

fn cmd_ldpc(args: &Args) {
    let niter = args.get("niter", 10u32);
    let variant = match args.str("variant", "sm").as_str() {
        "paper" => MinsumVariant::PaperListing,
        _ => MinsumVariant::SignMagnitude,
    };
    let flips: Vec<usize> = args
        .flags
        .get("flip")
        .map(|s| s.split(',').map(|x| x.parse().unwrap()).collect())
        .unwrap_or_default();
    let dec = LdpcNocDecoder::fano_on_mesh(variant, niter);
    let llr = codeword_llrs(&[0; 7], 100, &flips);
    println!("LDPC Fano decode over 4x4 mesh, niter={niter}, flips={flips:?}");
    let run = dec.decode(&llr, None);
    println!(
        "  single FPGA : bits {:?} valid={} cycles={} flits={}",
        run.result.bits,
        run.result.valid_codeword,
        run.report.cycles,
        run.report.net.delivered
    );
    if args.has("partition") {
        let p = dec.fig9_partition();
        let split = dec.decode(&llr, Some((&p, SerdesConfig::default())));
        println!(
            "  2 FPGAs     : bits {:?} cycles={} (+{} serdes cycles)",
            split.result.bits,
            split.report.cycles,
            split.report.cycles - run.report.cycles
        );
    }
}

fn cmd_track(args: &Args) {
    let frames = args.get("frames", 8usize);
    let workers = args.get("workers", 4usize);
    let params = TrackerParams {
        n_particles: args.get("particles", 32usize),
        sigma: args.get("sigma", 3.0f64),
        roi_r: args.get("roi", 5i32),
        seed: args.get("seed", 7u64),
    };
    let video = synthetic_video(64, 48, frames, 6, args.get("vseed", 11u64));
    let tracker = PfilterNocTracker::on_mesh(workers, params);
    println!(
        "particle filter over NoC: {frames} frames, {} particles, {workers} workers",
        params.n_particles
    );
    let run = tracker.track(&video, video.truth[0], None);
    for (k, (&est, &truth)) in run.centers.iter().zip(&video.truth).enumerate() {
        println!("  frame {k:2}: est {est:?} truth {truth:?}");
    }
    println!("  cycles={} flits={}", run.report.cycles, run.report.net.delivered);
}

fn cmd_bmvm(args: &Args) {
    let n = args.get("n", 1024usize);
    let k = args.get("k", 4usize);
    let pes = args.get("pes", 64usize);
    let r = args.get("r", 10u32);
    let topo = args.str("topo", "mesh");
    let mut rng = Rng::new(args.get("seed", 3u64));
    let a = Gf2Matrix::random(n, n, &mut rng);
    let luts = WilliamsLuts::preprocess(&a, k);
    let v = BitVec::random(n, &mut rng);
    let sys = BmvmSystem::new(luts, pes, BmvmSystem::topology_for(&topo, pes));
    println!(
        "BMVM n={n} k={k} f={} PEs={pes} topo={topo} r={r} (LUTs {:.2} Mb BRAM)",
        sys.fold(),
        sys.bram_bits() as f64 / (1024.0 * 1024.0)
    );
    let run = sys.run(&v, r, None);
    assert_eq!(run.result, dense_power_matvec(&a, &v, r), "verify vs dense oracle");
    println!(
        "  cycles={} time={:.3} ms (incl. host link) flits={} — verified vs dense A^r v",
        run.report.cycles, run.time_ms, run.report.net.delivered
    );
}

const DFG_SAMPLE: &str = "input a;\ninput b;\nt0 = a + b;\nt1 = a * 7;\nt2 = t0 ^ t1;\nt3 = t2 min b;\nt4 = t3 << 2;\ny = t4 - a;\noutput y;\n";

fn cmd_dfg(args: &Args) {
    let cores = args.get("cores", 2usize);
    let src = args
        .flags
        .get("file")
        .map(|f| std::fs::read_to_string(f).expect("read program"))
        .unwrap_or_else(|| DFG_SAMPLE.to_string());
    let g = dfg::parse(&src).expect("parse straight-line code");
    let prog = mips::compile(&g, cores);
    println!("; DFG: {} nodes, {} outputs, {} cores", g.nodes.len(), g.outputs.len(), cores);
    print!("{}", prog.listing());
    let a_args: Vec<u32> = (0..g.inputs.len()).map(|i| 10 + 3 * i as u32).collect();
    let run = mips::run(&prog, &g, &a_args, 1_000_000);
    println!("; inputs {a_args:?} -> outputs {:?} (oracle {:?})", run.outputs, g.eval(&a_args));
    println!("; {} cycles, blocked/core {:?}", run.cycles, run.blocked);
    assert_eq!(run.outputs, g.eval(&a_args));
}

fn cmd_noc(args: &Args) {
    let eps = args.get("endpoints", 16usize);
    let topo = topo_from_name(&args.str("topo", "mesh4x4"), eps);
    let flits = args.get("flits", 5000u32);
    let mut net = Network::new(&topo, NocConfig::paper());
    let n = net.n_endpoints();
    let mut rng = Rng::new(args.get("seed", 1u64));
    for i in 0..flits {
        let s = rng.index(n);
        let d = (s + 1 + rng.index(n - 1)) % n;
        net.inject(s, Flit::single(s, d, i, i as u64));
    }
    let cycles = net.run_until_idle(100_000_000).expect("network stalled");
    println!("{topo:?}: {} endpoints, {flits} flits uniform-random", n);
    println!("  drained in {cycles} cycles — {}", net.stats());
    let g = net.topo();
    println!("  avg hops {:.2}, diameter {}", g.avg_hops(), g.diameter());
}

fn cmd_scenarios(args: &Args) {
    let eps = args.get("endpoints", 64usize);
    let topo = topo_from_name(&args.str("topo", "mesh8x8"), eps);
    let engine = match args.str("engine", "event").as_str() {
        "ref" | "reference" => SimEngine::Reference,
        "event" | "event-driven" => SimEngine::EventDriven,
        other => panic!("unknown engine '{other}' (reference, event)"),
    };
    let load = args.get("load", 0.05f64);
    let cycles = args.get("cycles", 2_000u64);
    let seed = args.get("seed", 1u64);
    let which = args.str("scenario", "all");
    // --chips N (N >= 2) runs the sharded multi-FPGA co-simulation:
    // Partition::balanced over N chips, cut links on quasi-serdes wires.
    let chips = args.get("chips", 0usize);
    let cfg = NocConfig { engine, ..NocConfig::paper() };
    let partition = (chips >= 2).then(|| Partition::balanced(&topo.build(), chips, seed));
    let serdes = SerdesConfig {
        pins: args.get("pins", 8u32),
        clock_div: args.get("clock-div", 1u32),
        tx_buffer: 8,
    };
    println!(
        "scenario matrix on {topo:?} — {} engine, load {load}, {cycles}-cycle window, seed {seed}{}",
        engine.name(),
        if chips >= 2 {
            format!(", sharded across {chips} FPGAs ({} pins)", serdes.pins)
        } else {
            String::new()
        }
    );
    let mut matched = false;
    for scn in scenario::registry() {
        if which != "all" && scn.name != which {
            continue;
        }
        matched = true;
        let outcome = match &partition {
            Some(p) => {
                let sharding = scenario::Sharding { partition: p, serdes };
                scenario::run_scenario_multichip(&scn, &topo, cfg, &sharding, load, cycles, seed)
            }
            None => scenario::run_scenario(&scn, &topo, cfg, load, cycles, seed),
        };
        match outcome {
            Ok(out) => {
                println!("  {:14} {}", scn.name, out.report);
                if let Some(busiest) =
                    out.report.links.iter().max_by_key(|l| l.active_cycles)
                {
                    println!(
                        "  {:14}   busiest link R{}→R{}: {} flits, {:.1}% occupied, {} stall cyc",
                        "",
                        busiest.from.0,
                        busiest.to.0,
                        busiest.carried,
                        100.0 * busiest.occupancy(out.report.net.cycles),
                        busiest.stall_cycles
                    );
                }
            }
            Err(stall) => println!("  {:14} STALLED: {stall}", scn.name),
        }
    }
    if !matched {
        eprintln!(
            "unknown scenario '{which}' (one of: {}, all)",
            scenario::registry()
                .iter()
                .map(|s| s.name)
                .collect::<Vec<_>>()
                .join(", ")
        );
        std::process::exit(2);
    }
}

fn cmd_sweep(args: &Args) {
    use std::time::Instant;
    let eps = args.get("endpoints", 64usize);
    let topo = topo_from_name(&args.str("topo", "mesh8x8"), eps);
    let engine = match args.str("engine", "event").as_str() {
        "ref" | "reference" => SimEngine::Reference,
        "event" | "event-driven" => SimEngine::EventDriven,
        other => panic!("unknown engine '{other}' (reference, event)"),
    };
    let threads = args.get("threads", fabricflow::fleet::default_threads());
    let cycles = args.get("cycles", 800u64);
    let loads: Vec<f64> = args
        .str("loads", "0.02,0.1")
        .split(',')
        .map(|s| s.trim().parse().expect("bad --loads entry"))
        .collect();
    // --seeds N sweeps seeds 1..=N.
    let seeds: Vec<u64> = (1..=args.get("seeds", 4u64)).collect();
    let which = args.str("scenario", "all");
    let scenarios: Vec<scenario::Scenario> = scenario::registry()
        .into_iter()
        .filter(|s| which == "all" || s.name == which)
        .collect();
    if scenarios.is_empty() {
        eprintln!("unknown scenario '{which}'");
        std::process::exit(2);
    }
    let cfg = NocConfig { engine, ..NocConfig::paper() };
    let grid = scenario::SweepGrid { topo: topo.clone(), cfg, scenarios, loads, seeds, cycles };
    let chips = args.get("chips", 0usize);
    let t = Instant::now();
    // (cells for the per-cell printout, merged stats for the aggregate)
    let (n_jobs, rows, mut agg) = if chips >= 2 {
        let partition = Partition::balanced(&topo.build(), chips, args.get("seed", 1u64));
        let pins: Vec<u32> = args
            .str("pins", "8")
            .split(',')
            .map(|s| s.trim().parse().expect("bad --pins entry"))
            .collect();
        let divs: Vec<u32> = args
            .str("clock-divs", "1")
            .split(',')
            .map(|s| s.trim().parse().expect("bad --clock-divs entry"))
            .collect();
        let mut serdes_points = Vec::new();
        for &p in &pins {
            for &d in &divs {
                serdes_points.push(SerdesConfig { pins: p, clock_div: d, tx_buffer: 8 });
            }
        }
        let cells = scenario::run_multichip_grid(&grid, &partition, &serdes_points, threads)
            .unwrap_or_else(|e| panic!("multichip sweep stalled: {e}"));
        let mut agg = fabricflow::noc::NetStats::default();
        let rows: Vec<String> = cells
            .iter()
            .map(|c| {
                agg.merge(&c.stats);
                format!(
                    "{:12} load {:<5} seed {:<3} {:>2} pins /{} div: {:>8} cyc {:>7} flits {:>6} wire | p50 {} p95 {} p99 {}",
                    c.scenario, c.load, c.seed, c.pins, c.clock_div, c.cycles,
                    c.stats.delivered, c.wire_flits,
                    c.stats.p50(), c.stats.p95(), c.stats.p99()
                )
            })
            .collect();
        (cells.len(), rows, agg)
    } else {
        let cells = scenario::run_grid(&grid, threads)
            .unwrap_or_else(|e| panic!("sweep stalled: {e}"));
        let mut agg = fabricflow::noc::NetStats::default();
        let rows: Vec<String> = cells
            .iter()
            .map(|c| {
                agg.merge(&c.stats);
                format!(
                    "{:12} load {:<5} seed {:<3} {:>8} cyc {:>7} flits | p50 {} p95 {} p99 {}",
                    c.scenario, c.load, c.seed, c.cycles, c.stats.delivered,
                    c.stats.p50(), c.stats.p95(), c.stats.p99()
                )
            })
            .collect();
        (cells.len(), rows, agg)
    };
    let wall = t.elapsed().as_secs_f64();
    println!(
        "fleet sweep on {topo:?} — {} engine, {n_jobs} jobs, {threads} thread(s){}",
        engine.name(),
        if chips >= 2 { format!(", {chips} FPGAs") } else { String::new() }
    );
    for row in rows {
        println!("  {row}");
    }
    agg.cycles = 0; // per-job clocks are independent; don't fake a fabric clock
    println!(
        "  aggregate: {} injected, {} delivered, avg lat {:.1}, p50 {} p95 {} p99 {}",
        agg.injected,
        agg.delivered,
        agg.avg_latency(),
        agg.p50(),
        agg.p95(),
        agg.p99()
    );
    println!("  {n_jobs} jobs in {:.1} ms — {:.1} jobs/sec", wall * 1e3, n_jobs as f64 / wall);
}

fn cmd_bench(args: &Args) {
    let quick = args.has("quick");
    let out = args.str("out", "BENCH_noc.json");
    let sel = match args.flags.get("only") {
        Some(s) => fabricflow::perf::BenchSelect::parse(s).unwrap_or_else(|| {
            eprintln!("bad --only '{s}' (comma-separated: points, multichip, sweep)");
            std::process::exit(2);
        }),
        None => fabricflow::perf::BenchSelect::ALL,
    };
    let report = fabricflow::perf::run_selected(quick, sel);
    // Table on stderr so `--out -` leaves stdout as pure, parseable JSON.
    eprint!("{}", report.render_table());
    // --only + an existing file: read-modify-write, preserving the
    // sections this run did not regenerate.
    let json = if sel.is_all() || out == "-" {
        report.to_json()
    } else {
        match std::fs::read_to_string(&out) {
            Ok(old) => fabricflow::perf::merge_sections(&old, &report, sel),
            Err(_) => report.to_json(),
        }
    };
    if out == "-" {
        print!("{json}");
    } else {
        std::fs::write(&out, &json).unwrap_or_else(|e| panic!("write {out}: {e}"));
        println!("wrote {out}");
    }
}

fn cmd_resources() {
    for d in [Device::ZC7020, Device::VIRTEX6_ML605, Device::DE0_NANO] {
        println!(
            "{:28} {:>7} FF {:>7} LUT {:>4} DSP {:>6} Kb BRAM",
            d.name,
            d.regs,
            d.luts,
            d.dsp,
            d.bram_bits / 1024
        );
    }
    println!();
    print!("{}", tables::table1());
}

fn cmd_partition_demo(args: &Args) {
    // Fig 5: 4-router custom NoC, R0 on its own FPGA.
    let topo = Topology::Custom {
        n_routers: 4,
        links: vec![(0, 1), (1, 2), (2, 3), (3, 0)],
        endpoint_router: vec![0, 1, 2, 3],
    };
    let p = Partition::island(4, &[0]);
    let serdes = SerdesConfig {
        pins: args.get("pins", 8u32),
        clock_div: args.get("clock-div", 1u32),
        tx_buffer: 8,
    };
    let g = topo.build();
    println!("Fig 5 demo: 4-router NoC, R0+N0 on FPGA 1, rest on FPGA 0");
    println!("  cut links: {:?}", p.cut_links(&g));
    println!("  pins/FPGA: {:?}", p.pins_per_fpga(&g, &serdes));
    let mut net = Network::new(&topo, NocConfig::paper());
    p.apply(&mut net, serdes);
    let mut rng = Rng::new(9);
    for i in 0..2000u32 {
        let s = rng.index(4);
        let d = (s + 1 + rng.index(3)) % 4;
        net.inject(s, Flit::single(s, d, i, i as u64));
    }
    let cycles = net.run_until_idle(10_000_000).expect("network stalled");
    println!("  2000 flits drained in {cycles} cycles — {}", net.stats());
    for ((r, port), ch) in net.serdes_channels() {
        println!(
            "  serdes at R{r}.p{port}: {} flits carried, {} cycles/flit",
            ch.carried, ch.ser_cycles
        );
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first().cloned() else {
        eprintln!(
            "usage: fabricflow <tables|ldpc|track|bmvm|dfg|noc|scenarios|sweep|bench|partition|resources> [flags]"
        );
        std::process::exit(2);
    };
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "tables" => cmd_tables(&args),
        "ldpc" => cmd_ldpc(&args),
        "track" => cmd_track(&args),
        "bmvm" => cmd_bmvm(&args),
        "dfg" => cmd_dfg(&args),
        "noc" => cmd_noc(&args),
        "scenarios" => cmd_scenarios(&args),
        "sweep" => cmd_sweep(&args),
        "bench" => cmd_bench(&args),
        "partition" => cmd_partition_demo(&args),
        "resources" => cmd_resources(),
        other => {
            eprintln!("unknown command '{other}'");
            std::process::exit(2);
        }
    }
}
