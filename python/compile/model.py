"""Layer-2 JAX models — the exported entry points the Rust runtime loads.

Each function here is jitted, lowered once by ``aot.py`` to HLO text and
executed from ``rust/src/runtime`` via PJRT; Python never runs on the
request path. Shapes are fixed at export time (see aot.py's manifest):

* ``ldpc_decode_fano``   — batched min-sum decode of the Fano code.
* ``bmvm_power``         — dense GF(2) A^r v with runtime-dynamic r.
* ``pfilter_weights``    — per-frame particle weighting + center update.
"""

import jax.numpy as jnp

from .kernels import bmvm, ldpc, pfilter

# Fixed LDPC iteration count baked into the artifact (mirrored by the
# Rust cross-check tests; change both together).
LDPC_NITER = 5


def ldpc_decode_fano(llrs):
    """llrs int32 [B, 7] -> final sums int32 [B, 7] (sign = decision)."""
    check_nb, bit_nb = ldpc.fano_neighbors()
    return (ldpc.ldpc_decode(llrs, check_nb, bit_nb, LDPC_NITER),)


def bmvm_power(a_packed, v_packed, r):
    """a uint32 [n, w], v uint32 [w], r int32 scalar -> uint32 [w]."""
    return (bmvm.gf2_power_matvec(a_packed, v_packed, r),)


def pfilter_weights(ref_hist, cand_hists, particles):
    """(ref int32 [16], cands int32 [N, 16], particles int32 [N, 2]) ->
    (center int64 [2], rho int64 [N])."""
    center, rho = pfilter.pf_weights(ref_hist, cand_hists, particles)
    return (center, rho)
