"""AOT bridge: lower the Layer-2 models to HLO *text* artifacts.

HLO text — NOT a serialized HloModuleProto — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One hard-won gotcha (cross-checked by rust/tests/runtime_xla.rs):
``as_hlo_text(print_large_constants=True)`` is MANDATORY. The default
elides big constants (e.g. gather index tables) as ``{...}``, and the
0.5.1 text parser silently misparses the elision as an iota-like
literal — artifacts then compute garbage only on the Rust side while
eager JAX stays correct.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (what
``make artifacts`` runs). Emits one ``<name>.hlo.txt`` per model plus a
``manifest.txt`` recording the exported shapes.
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Export shapes (fixed at AOT time; mirrored in rust/src/runtime).
LDPC_BATCH = 16
BMVM_N = 64
PF_PARTICLES = 64
PF_BINS = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def exports():
    """(name, fn, example args) for every artifact."""
    s = jax.ShapeDtypeStruct
    return [
        (
            "ldpc_fano_b%d_i%d" % (LDPC_BATCH, model.LDPC_NITER),
            model.ldpc_decode_fano,
            (s((LDPC_BATCH, 7), jnp.int32),),
        ),
        (
            "bmvm_pow_n%d" % BMVM_N,
            model.bmvm_power,
            (
                s((BMVM_N, BMVM_N // 32), jnp.uint32),
                s((BMVM_N // 32,), jnp.uint32),
                s((), jnp.int32),
            ),
        ),
        (
            "pfilter_weights_n%d" % PF_PARTICLES,
            model.pfilter_weights,
            (
                s((PF_BINS,), jnp.int32),
                s((PF_PARTICLES, PF_BINS), jnp.int32),
                s((PF_PARTICLES, 2), jnp.int32),
            ),
        ),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []
    for name, fn, example in exports():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, name + ".hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        shapes = ", ".join(str(a.shape) + ":" + str(a.dtype) for a in example)
        manifest.append(f"{name}: ({shapes})")
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")


if __name__ == "__main__":
    main()
