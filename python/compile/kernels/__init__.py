"""Layer-1 Pallas kernels for the three case-study compute hot-spots.

Every kernel is lowered with ``interpret=True`` — the CPU PJRT plugin
cannot execute Mosaic custom-calls, so interpret mode (plain HLO ops) is
the correctness path; real-TPU efficiency is estimated in DESIGN.md from
the BlockSpec structure instead (see the Hardware-Adaptation section).
"""
