"""Layer-1 Pallas kernel: Bhattacharyya particle matching.

The Fig 11 FPGA datapath computes, per particle, sum_b sqrt(p_b * q_b)
with one 18x18 multiplier and an iterative isqrt; the TPU analogue
evaluates all N particles x 16 bins as one VMEM tile (the f64 sqrt is
exact for counts < 2^18, so the integer floor matches the FPGA's isqrt
bit-for-bit — the same argument as rust's histo::isqrt contract).

The Layer-2 model adds the root node's weighted-mean center update
(w = rho^4) so the whole per-frame particle step is one artifact.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rho_kernel(ref_ref, cand_ref, rho_ref):
    p = ref_ref[...].astype(jnp.int64)  # [BINS]
    q = cand_ref[...].astype(jnp.int64)  # [N, BINS]
    prod = p[None, :] * q
    root = jnp.floor(jnp.sqrt(prod.astype(jnp.float64))).astype(jnp.int64)
    rho_ref[...] = jnp.sum(root, axis=1)


def bhattacharyya_rho(ref_hist, cand_hists):
    """rho [N] int64 from ref [BINS] and candidates [N, BINS] (int32)."""
    n = cand_hists.shape[0]
    return pl.pallas_call(
        _rho_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int64),
        interpret=True,
    )(ref_hist, cand_hists)


def pf_weights(ref_hist, cand_hists, particles):
    """Layer-2 model: (center [2] int64, rho [N] int64).

    Same contract as ref.pf_weights_ref: w = rho^4, integer weighted mean
    of particle coordinates.
    """
    rho = bhattacharyya_rho(ref_hist, cand_hists)
    w = rho * rho
    w = w * w
    tot = jnp.sum(w)
    px = jnp.sum(w * particles[:, 0].astype(jnp.int64))
    py = jnp.sum(w * particles[:, 1].astype(jnp.int64))
    center = jnp.stack([px // jnp.maximum(tot, 1), py // jnp.maximum(tot, 1)])
    return center, rho
