"""Layer-1 Pallas kernel: LDPC min-sum check-node update.

The FPGA gets throughput from 7 parallel degree-3 comparator datapaths
(paper Fig 7); the TPU analogue is the same arithmetic vectorized over
(batch × checks) in a VMEM-resident tile. The kernel consumes the
bit→check messages u [B, m, deg] and produces the check→bit messages
v [B, m, deg]:

    v_j = (prod of signs over k != j) * (min |u_k| over k != j)

For the paper's PG codes deg is a small static constant (3 for the Fano
code), so the k != j reductions unroll into straight-line VPU code — the
exact structure of the Fig 7 comparator tree, replicated across the tile.

Hardware adaptation note (DESIGN.md §Hardware-Adaptation): lowered with
``interpret=True`` for the CPU PJRT runtime; on a real TPU the natural
BlockSpec tiles B into VMEM-sized chunks with deg kept minor-most.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _check_kernel(u_ref, v_ref, *, deg):
    u = u_ref[...]
    sign = jnp.where(u < 0, -1, 1).astype(jnp.int32)
    mag = jnp.abs(u)
    outs = []
    for j in range(deg):
        others = [k for k in range(deg) if k != j]
        s = sign[..., others[0]]
        m = mag[..., others[0]]
        for k in others[1:]:
            s = s * sign[..., k]
            m = jnp.minimum(m, mag[..., k])
        outs.append(ref.sat(s * m))
    v_ref[...] = jnp.stack(outs, axis=-1)


def check_update(u):
    """Pallas check-node update; u int32 [B, m, deg] -> v [B, m, deg]."""
    deg = u.shape[-1]
    return pl.pallas_call(
        functools.partial(_check_kernel, deg=deg),
        out_shape=jax.ShapeDtypeStruct(u.shape, jnp.int32),
        interpret=True,
    )(u)


def ldpc_decode(llrs, check_nb, bit_nb, niter):
    """Layer-2 model: batched flooding min-sum decode calling the Pallas
    check kernel; the bit update (Listing 3) is plain fused jnp.

    Same contract as ref.ldpc_decode_ref (returns the final sums whose
    signs are the decisions).
    """
    import numpy as np

    llrs = ref.sat(llrs.astype(jnp.int32))
    cnb = np.asarray(check_nb)
    bnb = np.asarray(bit_nb)
    m, deg = cnb.shape
    n = bnb.shape[0]
    c2b_pos = np.zeros_like(cnb)
    for c in range(m):
        for j in range(deg):
            c2b_pos[c, j] = list(bnb[cnb[c, j]]).index(c)
    b2c_pos = np.zeros_like(bnb)
    for b in range(n):
        for j in range(deg):
            b2c_pos[b, j] = list(cnb[bnb[b, j]]).index(b)

    u = llrs[:, cnb.reshape(-1)].reshape(llrs.shape[0], m, deg)
    sums = jnp.zeros_like(llrs)
    for _ in range(int(niter)):
        vc = check_update(u)  # Pallas kernel
        # Gather (not scatter — the xla_extension 0.5.1 runtime the Rust
        # side uses mis-executes jax's scatter lowering; gathers round-trip
        # cleanly): v[b, bit, pos] = vc[b, bit_nb[bit,pos], b2c_pos[bit,pos]].
        v = vc[:, bnb.reshape(-1), b2c_pos.reshape(-1)].reshape(
            vc.shape[0], n, deg
        )
        sums, outs = ref.bit_update_ref(llrs, v)
        # u[b, c, j] = outs[b, cnb[c,j], c2b_pos[c,j]].
        u = outs[:, cnb.reshape(-1), c2b_pos.reshape(-1)].reshape(
            outs.shape[0], m, deg
        )
    return sums


def fano_neighbors():
    """The PG(2,2) (Fano plane) code's edge structure, identical to
    rust's PgLdpcCode::fano() construction (points/lines over GF(2)
    homogeneous coordinates, first-nonzero-normalized, in enumeration
    order)."""
    import numpy as np

    # Points: (1,a,b) for a,b in GF(2); (0,1,b); (0,0,1) — same order as
    # gf2::pg::points.
    pts = [(1, a, b) for a in range(2) for b in range(2)]
    pts += [(0, 1, b) for b in range(2)]
    pts += [(0, 0, 1)]
    lines = pts
    incident = lambda p, l: (p[0] & l[0]) ^ (p[1] & l[1]) ^ (p[2] & l[2]) == 0
    check_nb = np.array(
        [[i for i, p in enumerate(pts) if incident(p, l)] for l in lines],
        dtype=np.int32,
    )
    bit_nb = np.array(
        [[c for c, l in enumerate(lines) if incident(pts[b], l)] for b in range(7)],
        dtype=np.int32,
    )
    return check_nb, bit_nb
