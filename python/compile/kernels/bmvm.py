"""Layer-1 Pallas kernel: dense GF(2) matrix-vector multiply on packed
words — the verification path for the BMVM case study (the Williams LUT
method is the *hardware* path; this dense kernel is the XLA-resident
oracle the Rust runtime cross-checks results against, and the baseline
for the k-crossover ablation).

GF(2) arithmetic maps to bitwise ops on packed uint32 lanes: a row-vector
product is AND + popcount-parity, which is VPU-friendly (no MXU needed) —
the TPU adaptation of the paper's BRAM-lookup datapath discussed in
DESIGN.md §Hardware-Adaptation.
"""

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _matvec_kernel(a_ref, v_ref, parity_ref):
    a = a_ref[...]  # [n, w] uint32
    v = v_ref[...]  # [w] uint32
    pops = lax.population_count(jnp.bitwise_and(a, v[None, :]))
    parity_ref[...] = (jnp.sum(pops.astype(jnp.uint32), axis=1) & jnp.uint32(1))


def gf2_matvec(a_packed, v_packed):
    """y = A v over GF(2), packed uint32 rows; matches ref.gf2_matvec_ref."""
    n, _w = a_packed.shape
    parity = pl.pallas_call(
        _matvec_kernel,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=True,
    )(a_packed, v_packed)
    # Pack the n parity bits LSB-first into ceil(n/32) words (fused XLA).
    w = (n + 31) // 32
    pad = w * 32 - n
    bits = jnp.concatenate([parity, jnp.zeros(pad, jnp.uint32)]).reshape(w, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts[None, :], axis=1).astype(jnp.uint32)


def gf2_power_matvec(a_packed, v_packed, r):
    """Layer-2 model: v <- A^r v with a dynamic trip count.

    `r` is a traced int32 scalar, lowered to an HLO while-loop so one AOT
    artifact serves every iteration count in Tables IV-V.
    """
    def body(_i, x):
        return gf2_matvec(a_packed, x)

    return lax.fori_loop(0, r, body, v_packed)
