"""Pure-jnp oracles for the Layer-1 Pallas kernels.

These are the correctness contracts: ``pytest python/tests`` asserts the
Pallas kernels reproduce these exactly (integer arithmetic throughout, so
equality is bitwise). The Rust side holds the mirror-image contracts: its
native datapaths are asserted equal to the AOT artifacts produced from
the L2 models that call these kernels.

All fixed-point conventions mirror ``rust/src/apps``:

* LDPC LLRs saturate to the symmetric i16 range [-32767, 32767]
  (``apps::ldpc::sat``).
* BMVM packs GF(2) vectors LSB-first into uint32 words
  (``util::bits::BitVec``).
* Particle weights use rho = sum_b floor(sqrt(p_b * q_b)) and w = rho^4
  (``apps::pfilter::histo``).
"""

import jax.numpy as jnp
from jax import lax

LLR_MAX = 32767
LLR_MIN = -32767


def sat(x):
    """Saturating clamp to the LLR range (mirrors apps::ldpc::sat).

    Bounds are explicit int32 scalars: with x64 enabled python ints become
    s64 constants, and the mixed s64/s32 clip call miscompiles on the
    xla_extension 0.5.1 runtime the Rust side executes artifacts with.
    """
    return jnp.clip(x, jnp.int32(LLR_MIN), jnp.int32(LLR_MAX))


# --------------------------------------------------------------------------
# LDPC min-sum (sign-magnitude variant), flooding schedule.
# --------------------------------------------------------------------------

def check_update_ref(u):
    """Check-node update on messages u [..., deg] -> v [..., deg].

    v_j = (prod of signs over k != j) * (min of |u_k| over k != j),
    saturated. Matches minsum::check_update(SignMagnitude).
    """
    deg = u.shape[-1]
    sign = jnp.where(u < 0, -1, 1).astype(jnp.int32)
    mag = jnp.abs(u)
    outs = []
    for j in range(deg):
        others = [k for k in range(deg) if k != j]
        s = sign[..., others[0]]
        m = mag[..., others[0]]
        for k in others[1:]:
            s = s * sign[..., k]
            m = jnp.minimum(m, mag[..., k])
        outs.append(sat(s * m))
    return jnp.stack(outs, axis=-1)


def bit_update_ref(u0, v):
    """Bit-node update (Listing 3) with per-add saturation.

    u0 [...,], v [..., deg] -> (sums [...], outs [..., deg]).
    Matches minsum::bit_update: sum = sat(...sat(u0 + v0) + v1...),
    out_j = sat(sum - v_j).
    """
    s = u0
    for k in range(v.shape[-1]):
        s = sat(s + v[..., k])
    outs = sat(s[..., None] - v)
    return s, outs


def ldpc_decode_ref(llrs, check_nb, bit_nb, niter):
    """Batched flooding min-sum decode.

    llrs: int32 [B, N]; check_nb [m, deg] bit index per check edge;
    bit_nb [N, deg] check index per bit edge. Returns final sums [B, N]
    (sign = decision). Bit-exact mirror of ReferenceDecoder::decode with
    MinsumVariant::SignMagnitude.
    """
    llrs = sat(llrs.astype(jnp.int32))
    m, deg = check_nb.shape
    n = bit_nb.shape[0]
    # u[b, c, j]: message bit->check along check c's edge j.
    u = llrs[:, check_nb.reshape(-1)].reshape(llrs.shape[0], m, deg)
    # Index maps between edge coordinate systems:
    # for check c edge j (bit b), the position of c in bit b's list.
    import numpy as np

    cnb = np.asarray(check_nb)
    bnb = np.asarray(bit_nb)
    c2b_pos = np.zeros_like(cnb)
    for c in range(m):
        for j in range(deg):
            b = cnb[c, j]
            c2b_pos[c, j] = list(bnb[b]).index(c)
    b2c_pos = np.zeros_like(bnb)
    for b in range(n):
        for j in range(deg):
            c = bnb[b, j]
            b2c_pos[b, j] = list(cnb[c]).index(b)

    sums = jnp.zeros_like(llrs)
    for _ in range(niter):
        vc = check_update_ref(u)  # [B, m, deg] messages check->bit
        # Re-index to bit coordinates by gathering:
        # v[b, bit, pos] = vc[b, bit_nb[bit,pos], b2c_pos[bit,pos]].
        v = vc[:, bnb.reshape(-1), b2c_pos.reshape(-1)].reshape(
            vc.shape[0], n, deg
        )
        sums, outs = bit_update_ref(llrs, v)
        # u[b, c, j] = outs[b, cnb[c,j], c2b_pos[c,j]].
        u = outs[:, cnb.reshape(-1), c2b_pos.reshape(-1)].reshape(
            outs.shape[0], m, deg
        )
    return sums


# --------------------------------------------------------------------------
# GF(2) dense matvec on packed words.
# --------------------------------------------------------------------------

def gf2_matvec_ref(a_packed, v_packed):
    """y = A @ v over GF(2).

    a_packed: uint32 [n, w] (row-major, bit i of word j = column 32j+i),
    v_packed: uint32 [w]. Returns uint32 [w] packed result (LSB-first),
    mirroring Gf2Matrix::matvec / BitVec packing.
    """
    n = a_packed.shape[0]
    anded = jnp.bitwise_and(a_packed, v_packed[None, :])
    pops = lax.population_count(anded).astype(jnp.uint32)
    parity = jnp.sum(pops, axis=1) & jnp.uint32(1)  # [n] 0/1
    # Pack LSB-first into n/32 words.
    w = (n + 31) // 32
    pad = w * 32 - n
    bits = jnp.concatenate([parity, jnp.zeros(pad, jnp.uint32)])
    bits = bits.reshape(w, 32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(bits << shifts[None, :], axis=1).astype(jnp.uint32)


def gf2_power_matvec_ref(a_packed, v_packed, r):
    """v <- A^r v by repeated multiplication (r static)."""
    x = v_packed
    for _ in range(int(r)):
        x = gf2_matvec_ref(a_packed, x)
    return x


# --------------------------------------------------------------------------
# Particle-filter weights.
# --------------------------------------------------------------------------

def bhattacharyya_rho_ref(ref_hist, cand_hists):
    """rho[i] = sum_b floor(sqrt(ref[b] * cand[i, b])), int64.

    Mirrors histo::bhattacharyya_rho (counts < 2^18, so the f64 sqrt is
    exact enough for an exact floor).
    """
    prod = ref_hist.astype(jnp.int64)[None, :] * cand_hists.astype(jnp.int64)
    root = jnp.floor(jnp.sqrt(prod.astype(jnp.float64))).astype(jnp.int64)
    return jnp.sum(root, axis=1)


def pf_weights_ref(ref_hist, cand_hists, particles):
    """(center [2] int64, rho [N] int64): weighted-mean center update.

    w = rho^4 (histo::particle_weight), center = sum(w*p)/sum(w) with the
    previous center NOT modeled here (callers guard the all-zero case).
    Mirrors histo::weighted_mean for nonzero total weight.
    """
    rho = bhattacharyya_rho_ref(ref_hist, cand_hists)
    w = rho * rho
    w = w * w  # rho^4
    tot = jnp.sum(w)
    px = jnp.sum(w * particles[:, 0].astype(jnp.int64))
    py = jnp.sum(w * particles[:, 1].astype(jnp.int64))
    center = jnp.stack([px // jnp.maximum(tot, 1), py // jnp.maximum(tot, 1)])
    return center, rho
