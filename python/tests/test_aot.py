"""AOT export sanity: every model lowers to parseable HLO text with the
declared signature, and the lowered module computes the same values as
the eager model (CPU execution of the exported computation)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_all_exports_lower_to_hlo_text():
    for name, fn, example in aot.exports():
        lowered = jax.jit(fn).lower(*example)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        assert len(text) > 200, name


def test_ldpc_artifact_shape_contract():
    (name, fn, example) = aot.exports()[0]
    assert "ldpc" in name
    out = jax.jit(fn)(jnp.zeros(example[0].shape, jnp.int32))
    assert out[0].shape == (aot.LDPC_BATCH, 7)


def test_bmvm_artifact_executes_identity():
    _, fn, _ = aot.exports()[1]
    n = aot.BMVM_N
    eye = np.zeros((n, n // 32), np.uint32)
    for i in range(n):
        eye[i, i // 32] = np.uint32(1) << (i % 32)
    v = np.arange(n // 32, dtype=np.uint32) + 7
    (out,) = jax.jit(fn)(jnp.asarray(eye), jnp.asarray(v), jnp.int32(3))
    np.testing.assert_array_equal(np.asarray(out), v)


def test_manifest_written(tmp_path):
    import subprocess
    import sys
    import os

    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path)],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
        env=env,
        check=True,
    )
    assert "wrote" in r.stdout
    files = sorted(p.name for p in tmp_path.iterdir())
    assert "manifest.txt" in files
    assert sum(f.endswith(".hlo.txt") for f in files) == 3
