"""Kernel-vs-oracle correctness: the CORE L1 signal.

Every Pallas kernel must equal its pure-jnp oracle bitwise (all integer
arithmetic); hypothesis sweeps data values, batch sizes and degrees.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bmvm, ldpc, pfilter, ref

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


# --------------------------------------------------------------------------
# LDPC
# --------------------------------------------------------------------------

@given(
    st.integers(1, 4),  # batch
    st.integers(2, 5),  # checks
    st.sampled_from([2, 3, 4]),  # degree
    st.integers(0, 2**32 - 1),
)
def test_check_update_matches_ref(b, m, deg, seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.integers(-32767, 32768, size=(b, m, deg)), jnp.int32)
    got = ldpc.check_update(u)
    want = ref.check_update_ref(u)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_check_update_listing2_example():
    # minsum.rs sign-magnitude unit vector: [5, -3, 7] -> [-3, 5, -3].
    u = jnp.asarray([[[5, -3, 7]]], jnp.int32)
    v = np.asarray(ldpc.check_update(u))[0, 0]
    np.testing.assert_array_equal(v, [-3, 5, -3])


@given(st.integers(0, 2**32 - 1), st.integers(1, 6))
def test_ldpc_decode_kernel_matches_ref(seed, niter):
    rng = np.random.default_rng(seed)
    check_nb, bit_nb = ldpc.fano_neighbors()
    llrs = jnp.asarray(rng.integers(-200, 201, size=(4, 7)), jnp.int32)
    got = ldpc.ldpc_decode(llrs, check_nb, bit_nb, niter)
    want = ref.ldpc_decode_ref(llrs, check_nb, bit_nb, niter)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_fano_structure_matches_rust_construction():
    check_nb, bit_nb = ldpc.fano_neighbors()
    assert check_nb.shape == (7, 3)
    assert bit_nb.shape == (7, 3)
    # Any two lines meet in exactly one point.
    for i in range(7):
        for j in range(i + 1, 7):
            assert len(set(check_nb[i]) & set(check_nb[j])) == 1


def test_clean_codeword_decodes_positive():
    check_nb, bit_nb = ldpc.fano_neighbors()
    llrs = jnp.full((2, 7), 100, jnp.int32)
    sums = ldpc.ldpc_decode(llrs, check_nb, bit_nb, 5)
    assert bool(jnp.all(sums > 0))


def test_single_flip_corrected():
    check_nb, bit_nb = ldpc.fano_neighbors()
    llrs = np.full((7, 7), 100, np.int32)
    for flip in range(7):
        llrs[flip, flip] = -100
    sums = ldpc.ldpc_decode(jnp.asarray(llrs), check_nb, bit_nb, 5)
    assert bool(jnp.all(sums > 0)), "all single flips decode to all-zeros"


# --------------------------------------------------------------------------
# BMVM
# --------------------------------------------------------------------------

def _pack_rows(bits):
    """bits [n, n] 0/1 -> packed uint32 [n, n/32] LSB-first."""
    n = bits.shape[1]
    w = (n + 31) // 32
    out = np.zeros((bits.shape[0], w), np.uint32)
    for j in range(n):
        out[:, j // 32] |= (bits[:, j].astype(np.uint32)) << (j % 32)
    return out


@given(st.integers(0, 2**32 - 1), st.sampled_from([32, 64, 96]))
def test_gf2_matvec_matches_ref_and_numpy(seed, n):
    rng = np.random.default_rng(seed)
    a_bits = rng.integers(0, 2, size=(n, n)).astype(np.uint32)
    v_bits = rng.integers(0, 2, size=n).astype(np.uint32)
    a = jnp.asarray(_pack_rows(a_bits))
    v = jnp.asarray(_pack_rows(v_bits[None, :])[0])
    got = bmvm.gf2_matvec(a, v)
    want = ref.gf2_matvec_ref(a, v)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # Independent numpy oracle.
    y_bits = (a_bits @ v_bits) % 2
    np.testing.assert_array_equal(
        np.asarray(got), _pack_rows(y_bits[None, :].astype(np.uint32))[0]
    )


@given(st.integers(0, 2**32 - 1), st.integers(0, 6))
def test_gf2_power_dynamic_r(seed, r):
    rng = np.random.default_rng(seed)
    n = 64
    a_bits = rng.integers(0, 2, size=(n, n)).astype(np.uint32)
    v_bits = rng.integers(0, 2, size=n).astype(np.uint32)
    a = jnp.asarray(_pack_rows(a_bits))
    v = jnp.asarray(_pack_rows(v_bits[None, :])[0])
    got = bmvm.gf2_power_matvec(a, v, jnp.int32(r))
    want = ref.gf2_power_matvec_ref(a, v, r)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_gf2_identity_is_noop():
    n = 64
    a = jnp.asarray(_pack_rows(np.eye(n, dtype=np.uint32)))
    v = jnp.asarray(np.array([0xDEADBEEF, 0x12345678], np.uint32))
    got = bmvm.gf2_power_matvec(a, v, jnp.int32(5))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(v))


# --------------------------------------------------------------------------
# Particle filter
# --------------------------------------------------------------------------

@given(st.integers(0, 2**32 - 1), st.sampled_from([8, 64, 100]))
def test_rho_kernel_matches_ref(seed, n):
    rng = np.random.default_rng(seed)
    ref_h = jnp.asarray(rng.integers(0, 400, size=16), jnp.int32)
    cands = jnp.asarray(rng.integers(0, 400, size=(n, 16)), jnp.int32)
    got = pfilter.bhattacharyya_rho(ref_h, cands)
    want = ref.bhattacharyya_rho_ref(ref_h, cands)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rho_isqrt_is_exact_floor():
    # Perfect squares and off-by-one cases.
    ref_h = jnp.asarray([9, 9, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0], jnp.int32)
    cands = jnp.asarray([[4, 5] + [0] * 14], jnp.int32)
    rho = np.asarray(pfilter.bhattacharyya_rho(ref_h, cands))
    # isqrt(36)=6, isqrt(45)=6.
    assert rho[0] == 12


@given(st.integers(0, 2**32 - 1))
def test_pf_weights_matches_ref(seed):
    rng = np.random.default_rng(seed)
    n = 64
    ref_h = jnp.asarray(rng.integers(0, 300, size=16), jnp.int32)
    cands = jnp.asarray(rng.integers(0, 300, size=(n, 16)), jnp.int32)
    parts = jnp.asarray(rng.integers(0, 64, size=(n, 2)), jnp.int32)
    gc, gr = pfilter.pf_weights(ref_h, cands, parts)
    wc, wr = ref.pf_weights_ref(ref_h, cands, parts)
    np.testing.assert_array_equal(np.asarray(gc), np.asarray(wc))
    np.testing.assert_array_equal(np.asarray(gr), np.asarray(wr))


def test_pf_center_prefers_matching_particle():
    # One particle matches the reference exactly, others are empty bins.
    ref_h = jnp.asarray([100] * 16, jnp.int32)
    cands = np.zeros((4, 16), np.int32)
    cands[2] = 100
    parts = jnp.asarray([[0, 0], [10, 10], [30, 40], [63, 63]], jnp.int32)
    center, rho = pfilter.pf_weights(ref_h, jnp.asarray(cands), parts)
    assert int(rho[2]) > 0 and int(rho[0]) == 0
    np.testing.assert_array_equal(np.asarray(center), [30, 40])
