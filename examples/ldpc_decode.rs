//! Case study I driver (paper §IV, Fig 9): LDPC min-sum decoding of the
//! Fano-plane PG code over a 4×4 mesh NoC, single-FPGA and partitioned
//! across two FPGAs along the Fig 9 dotted arc, cross-checked against the
//! monolithic reference decoder and, optionally, the AOT-compiled
//! JAX/Pallas batch decoder via PJRT (build with `--features pjrt`
//! after adding the `xla`/`anyhow` dependencies per rust/Cargo.toml,
//! and run `make artifacts` first).
//!
//! Run: `cargo run --release --example ldpc_decode`

use fabricflow::apps::ldpc::mapper::LdpcNocDecoder;
use fabricflow::apps::ldpc::minsum::{codeword_llrs, MinsumVariant, ReferenceDecoder};
use fabricflow::gf2::pg::PgLdpcCode;
use fabricflow::serdes::SerdesConfig;
use fabricflow::util::Rng;

fn main() {
    let niter = 10;
    let dec = LdpcNocDecoder::fano_on_mesh(MinsumVariant::SignMagnitude, niter);
    let reference = ReferenceDecoder::new(PgLdpcCode::fano(), MinsumVariant::SignMagnitude);

    println!("== single-bit error sweep over the NoC decoder (Fig 9 mapping) ==");
    for flip in 0..7 {
        let llr = codeword_llrs(&[0; 7], 100, &[flip]);
        let run = dec.decode(&llr, None);
        assert_eq!(run.result.bits, vec![0; 7], "flip {flip} uncorrected");
        assert_eq!(run.result.sums, reference.decode(&llr, niter).sums);
        println!(
            "  flip bit {flip}: corrected in {} cycles ({} flits)",
            run.report.cycles, run.report.net.delivered
        );
    }

    println!("== Fig 9 dotted arc: 2-FPGA partition over 8-wire quasi-SERDES ==");
    let p = dec.fig9_partition();
    let mut rng = Rng::new(1);
    for trial in 0..3 {
        let llr: Vec<i32> = (0..7).map(|_| rng.range_i64(-120, 120) as i32).collect();
        let mono = dec.decode(&llr, None);
        let split = dec.decode(&llr, Some((&p, SerdesConfig::default())));
        assert_eq!(mono.result.sums, split.result.sums);
        println!(
            "  trial {trial}: 1 FPGA {} cycles, 2 FPGAs {} cycles ({}x slowdown)",
            mono.report.cycles,
            split.report.cycles,
            split.report.cycles as f64 / mono.report.cycles as f64
        );
    }

    println!("== scaling: PG(2,4), N = 21, degree 5, on an auto-sized mesh ==");
    let big = LdpcNocDecoder::pg_on_mesh(2, MinsumVariant::SignMagnitude, niter);
    let llr = codeword_llrs(&vec![0; 21], 100, &[2, 17]);
    let run = big.decode(&llr, None);
    assert_eq!(run.result.bits, vec![0; 21]);
    println!(
        "  two flipped bits corrected in {} cycles over {:?}",
        run.report.cycles, big.topo
    );

    xla_cross_check();
    println!("ldpc_decode OK");
}

#[cfg(feature = "pjrt")]
fn xla_cross_check() {
    use fabricflow::runtime::{artifacts_dir, XlaEngine, XlaLdpcDecoder, LDPC_NITER};
    if !artifacts_dir().exists() {
        println!("(artifacts/ missing — run `make artifacts` for the XLA cross-check)");
        return;
    }
    println!("== XLA artifact cross-check (JAX/Pallas via PJRT) ==");
    let engine = XlaEngine::cpu().expect("pjrt");
    let xdec = XlaLdpcDecoder::load(&engine).expect("artifact");
    let short = LdpcNocDecoder::fano_on_mesh(MinsumVariant::SignMagnitude, LDPC_NITER);
    let mut rng = Rng::new(2);
    let batch: Vec<[i32; 7]> = (0..16)
        .map(|_| {
            let mut row = [0i32; 7];
            for v in row.iter_mut() {
                *v = rng.range_i64(-150, 150) as i32;
            }
            row
        })
        .collect();
    let xla = xdec.decode_batch(&batch).expect("decode");
    for (row, sums) in batch.iter().zip(&xla) {
        let noc = short.decode(row, None);
        assert_eq!(noc.result.sums.as_slice(), sums.as_slice());
    }
    println!("  16 random LLR rows: NoC decoder == Pallas artifact, bit-exact");
}

#[cfg(not(feature = "pjrt"))]
fn xla_cross_check() {
    println!("(built without the `pjrt` feature — skipping the XLA cross-check)");
}
