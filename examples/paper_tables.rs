//! End-to-end driver: regenerate every table of the paper's evaluation
//! on the full workloads and print measured-vs-paper rows.
//!
//! * Tables I–III — resource models (instant).
//! * Table IV — BMVM n=64, k=8, f=2: 4 PEs on a 2×2 mesh vs the 4-thread
//!   software baseline, r ∈ {1, 10, 100, 1000}.
//! * Table V — BMVM n=1024, k=4, f=4: 64 PEs on ring/mesh/torus/fat-tree
//!   vs 64 threads, r ∈ {1, 10, 100, 1000}.
//!
//! `--quick` drops the r=1000 rows (CI runs); `--reps N` sets the
//! software-baseline averaging (paper used 100).
//!
//! Run: `cargo run --release --example paper_tables [-- --quick]`

use fabricflow::tables::{all_tables, TableOpts};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let quick = argv.iter().any(|a| a == "--quick");
    let reps = argv
        .iter()
        .position(|a| a == "--reps")
        .and_then(|i| argv.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(3);
    let opts = TableOpts { reps, quick, seed: 0x7AB1E };
    let t0 = std::time::Instant::now();
    print!("{}", all_tables(&opts));
    eprintln!("\n[paper_tables completed in {:?}]", t0.elapsed());
}
