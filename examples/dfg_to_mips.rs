//! Fig 2 driver: the compiler-driven phase-1 automation — straight-line
//! code → dataflow graph → partition → minimal-MIPS code with network
//! push/pull → execution on a network of MIPS cores over the NoC.
//!
//! Run: `cargo run --release --example dfg_to_mips`

use fabricflow::dfg;
use fabricflow::mips;
use fabricflow::util::Rng;

const PROGRAM: &str = "
    // A small filter kernel in the paper's 'straight line code' style.
    input x0;
    input x1;
    input x2;
    d0 = x0 + x1;
    d1 = x1 + x2;
    m0 = d0 * 3;
    m1 = d1 * 5;
    s  = m0 + m1;
    c  = s >> 2;
    lo = c min 255;
    hi = c max 16;
    y0 = lo ^ hi;
    y1 = y0 - x1;
    output y0;
    output y1;
";

fn main() {
    let g = dfg::parse(PROGRAM).expect("parse");
    println!(
        "DFG: {} nodes ({} inputs, {} outputs), depth {}",
        g.nodes.len(),
        g.inputs.len(),
        g.outputs.len(),
        g.levels().iter().max().unwrap()
    );

    let args = [12u32, 34, 56];
    let want = g.eval(&args);
    println!("sequential oracle: {args:?} -> {want:?}\n");

    for cores in [1usize, 2, 4] {
        let prog = mips::compile(&g, cores);
        let cuts = g.cut_edges(&prog.assignment).len();
        let run = mips::run(&prog, &g, &args, 1_000_000);
        assert_eq!(run.outputs, want, "{cores} cores");
        println!(
            "{cores} core(s): {} cycles, {cuts} cut edges -> push/pull pairs, \
             blocked cycles per core {:?}",
            run.cycles, run.blocked
        );
    }

    println!("\nGenerated assembly for 2 cores:");
    let prog = mips::compile(&g, 2);
    print!("{}", prog.listing());

    println!("\nRandomized sweep: 25 programs x (1,2,4) cores vs oracle");
    let mut rng = Rng::new(99);
    for t in 0..25 {
        let n_ops = 8 + rng.index(14);
        let g = dfg::random_program(&mut rng, n_ops);
        let args: Vec<u32> = (0..g.inputs.len()).map(|_| rng.next_u32()).collect();
        let want = g.eval(&args);
        for cores in [1usize, 2, 4] {
            let prog = mips::compile(&g, cores);
            let run = mips::run(&prog, &g, &args, 2_000_000);
            assert_eq!(run.outputs, want, "program {t}, {cores} cores");
        }
    }
    println!("dfg_to_mips OK");
}
