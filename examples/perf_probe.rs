use fabricflow::noc::{Flit, Network, NocConfig, Topology};
use fabricflow::util::Rng;
use std::time::Instant;
fn main() {
    let topo = Topology::Mesh { w: 8, h: 8 };
    let t = Instant::now();
    let mut nets: Vec<Network> = (0..50).map(|_| Network::new(&topo, NocConfig::paper())).collect();
    println!("build x50: {:?}", t.elapsed());
    let mut rng = Rng::new(1);
    let t = Instant::now();
    let mut total_cycles = 0u64;
    for net in nets.iter_mut() {
        for i in 0..10_000u32 {
            let s = rng.index(64);
            let d = (s + 1 + rng.index(63)) % 64;
            net.inject(s, Flit::single(s, d, i, i as u64));
        }
        total_cycles += net.run_until_idle(10_000_000).expect("network stalled");
    }
    let el = t.elapsed();
    println!("run x50 (10k flits each): {:?}, {} cycles total", el, total_cycles);
    println!("router-cycles/s: {:.2}M", (total_cycles * 64) as f64 / el.as_secs_f64() / 1e6);
    // per-cycle cost
    println!("ns/cycle: {:.0}", el.as_nanos() as f64 / total_cycles as f64);
}
