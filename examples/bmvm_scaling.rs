//! Case study III driver (paper §VI, Figs 13–14): Williams sub-quadratic
//! Boolean matrix-vector multiplication over the NoC — preprocessing,
//! folding, topology sweep, multi-FPGA partitioning, and, optionally,
//! the XLA dense oracle cross-check (`--features pjrt` after adding
//! the `xla`/`anyhow` dependencies per rust/Cargo.toml). This is the
//! communication-intensive workload that "shows the impact of the
//! choice of topology".
//!
//! Run: `cargo run --release --example bmvm_scaling`

use fabricflow::apps::bmvm::{
    dense_power_matvec, software, BmvmSystem, HostLink, WilliamsLuts,
};
use fabricflow::gf2::Gf2Matrix;
use fabricflow::partition::Partition;
use fabricflow::serdes::SerdesConfig;
use fabricflow::util::bits::BitVec;
use fabricflow::util::Rng;

fn main() {
    let mut rng = Rng::new(0xB14);

    println!("== preprocessing (Fig 13): LUT storage vs k ==");
    let a256 = Gf2Matrix::random(256, 256, &mut rng);
    for k in [2usize, 4, 8] {
        let luts = WilliamsLuts::preprocess(&a256, k);
        println!(
            "  n=256 k={k}: {} block-columns, {:.2} Mb BRAM, word-reads/multiply {}",
            luts.blocks,
            luts.storage_bits() as f64 / (1024.0 * 1024.0),
            luts.blocks * luts.blocks
        );
    }

    println!("== topology sweep (scaled Table V shape: n=256, k=4, 16 PEs) ==");
    let luts = WilliamsLuts::preprocess(&a256, 4);
    let v = BitVec::random(256, &mut rng);
    let expect = dense_power_matvec(&a256, &v, 20);
    for name in ["ring", "mesh", "torus", "fat_tree"] {
        let sys = BmvmSystem::new(luts.clone(), 16, BmvmSystem::topology_for(name, 16));
        let run = sys.run(&v, 20, None);
        assert_eq!(run.result, expect, "{name}");
        println!(
            "  {name:9}: {:>7} cycles, {:.3} ms incl. {:.3} ms host link",
            run.report.cycles,
            run.time_ms,
            HostLink::default().roundtrip_ms(256, 256)
        );
    }

    println!("== folding sweep (f = blocks / PEs) ==");
    for pes in [4usize, 16, 64] {
        let sys = BmvmSystem::new(luts.clone(), pes, BmvmSystem::topology_for("mesh", pes));
        let run = sys.run(&v, 20, None);
        assert_eq!(run.result, expect);
        println!("  {pes:2} PEs (f={}): {} cycles", sys.fold(), run.report.cycles);
    }

    println!("== hardware vs software vs dense oracle (n=256, r=50) ==");
    let sys = BmvmSystem::new(luts.clone(), 16, BmvmSystem::topology_for("torus", 16));
    let hw = sys.run(&v, 50, None);
    let sw = software::run_software(&luts, &v, 50, 16);
    assert_eq!(hw.result, sw.result);
    assert_eq!(hw.result, dense_power_matvec(&a256, &v, 50));
    println!(
        "  hw {:.3} ms | sw {:.3} ms | speedup {:.1}x",
        hw.time_ms,
        sw.elapsed.as_secs_f64() * 1e3,
        sw.elapsed.as_secs_f64() * 1e3 / hw.time_ms
    );

    println!("== 4-FPGA partition of the 16-PE torus ==");
    let topo = BmvmSystem::topology_for("torus", 16);
    let part = Partition::balanced(&topo.build(), 4, 11);
    let split = sys.run(&v, 50, Some((&part, SerdesConfig::default())));
    assert_eq!(split.result, hw.result);
    println!(
        "  sizes {:?}, {} cut links, {} cycles (vs {} single-FPGA)",
        part.sizes(),
        split.report.cut_links,
        split.report.cycles,
        hw.report.cycles
    );

    xla_cross_check();
    println!("bmvm_scaling OK");
}

#[cfg(feature = "pjrt")]
fn xla_cross_check() {
    use fabricflow::runtime::{artifacts_dir, XlaBmvm, XlaEngine, BMVM_N};
    if !artifacts_dir().exists() {
        println!("(artifacts/ missing — run `make artifacts` for the XLA cross-check)");
        return;
    }
    println!("== XLA dense-oracle artifact (n={BMVM_N}) ==");
    let mut rng = Rng::new(0xB15);
    let engine = XlaEngine::cpu().expect("pjrt");
    let bm = XlaBmvm::load(&engine).expect("artifact");
    let a = Gf2Matrix::random(BMVM_N, BMVM_N, &mut rng);
    let v64 = BitVec::random(BMVM_N, &mut rng);
    let pack = |b: &BitVec| -> Vec<u32> {
        let mut out = Vec::new();
        for w in b.words() {
            out.push((*w & 0xFFFF_FFFF) as u32);
            out.push((*w >> 32) as u32);
        }
        out.truncate(b.len().div_ceil(32));
        out
    };
    let a_rows: Vec<u32> = (0..BMVM_N).flat_map(|r| pack(a.row(r))).collect();
    let got = bm.power_matvec(&a_rows, &pack(&v64), 12).expect("run");
    assert_eq!(got, pack(&dense_power_matvec(&a, &v64, 12)));
    println!("  A^12·v via Pallas popcount kernel == rust dense oracle");
}

#[cfg(not(feature = "pjrt"))]
fn xla_cross_check() {
    println!("(built without the `pjrt` feature — skipping the XLA cross-check)");
}
