//! Case study II driver (paper §V, Figs 10–12): particle-filter object
//! tracking over the NoC — root orchestrator on Node 0, worker compute
//! elements, frame DMA and particle scatter/gather as NoC traffic —
//! validated against the monolithic reference tracker and the ground
//! truth of the synthetic video, plus, optionally, the AOT Pallas
//! weight kernel (`--features pjrt` after adding the `xla`/`anyhow`
//! dependencies per rust/Cargo.toml).
//!
//! Run: `cargo run --release --example object_tracking`

use fabricflow::apps::pfilter::{
    mean_error, synthetic_video, track_reference, PfilterNocTracker, TrackerParams, Video,
};
use fabricflow::partition::Partition;
use fabricflow::serdes::SerdesConfig;

fn main() {
    let video = synthetic_video(64, 48, 12, 6, 42);
    let params = TrackerParams { n_particles: 48, sigma: 3.5, roi_r: 6, seed: 9 };

    println!("== reference tracker (monolithic software oracle) ==");
    let reference = track_reference(&video, video.truth[0], &params);
    let err = mean_error(&reference, &video.truth);
    println!("  mean error vs ground truth: {err:.2} px over {} frames", video.frames.len());
    assert!(err < 5.0, "tracker must stay locked");

    println!("== NoC tracker (Fig 10: root on node 0 + 6 workers) ==");
    let noc = PfilterNocTracker::on_mesh(6, params);
    let run = noc.track(&video, video.truth[0], None);
    assert_eq!(run.centers, reference.centers, "NoC must equal the oracle");
    println!(
        "  {} cycles, {} flits (frame DMA + particles + gathers)",
        run.report.cycles, run.report.net.delivered
    );
    for (k, (&est, &truth)) in run.centers.iter().zip(&video.truth).enumerate().take(6) {
        println!("  frame {k:2}: est {est:?}  truth {truth:?}");
    }

    println!("== exploring variations (paper: 'makes exploring variations easier') ==");
    for workers in [2usize, 4, 8] {
        let t = PfilterNocTracker::on_mesh(workers, params);
        let r = t.track(&video, video.truth[0], None);
        assert_eq!(r.centers, reference.centers);
        println!("  {workers} workers: {} cycles", r.report.cycles);
    }

    println!("== 2-FPGA partition ==");
    let part = Partition::balanced(&noc.topo.build(), 2, 5);
    let split = noc.track(&video, video.truth[0], Some((&part, SerdesConfig::default())));
    assert_eq!(split.centers, reference.centers);
    println!(
        "  same trajectory, {} cycles (vs {} single-FPGA), {} links cut",
        split.report.cycles, run.report.cycles, split.report.cut_links
    );

    xla_cross_check(&video);
    println!("object_tracking OK");
}

#[cfg(feature = "pjrt")]
fn xla_cross_check(video: &Video) {
    use fabricflow::apps::pfilter::histo::{weighted_histogram, BINS};
    use fabricflow::runtime::{artifacts_dir, XlaEngine, XlaPfWeights, PF_PARTICLES};
    use fabricflow::util::Rng;
    if !artifacts_dir().exists() {
        println!("(artifacts/ missing — run `make artifacts` for the XLA cross-check)");
        return;
    }
    println!("== XLA artifact cross-check (Pallas Bhattacharyya kernel) ==");
    let engine = XlaEngine::cpu().expect("pjrt");
    let pf = XlaPfWeights::load(&engine).expect("artifact");
    let mut rng = Rng::new(3);
    let (cx, cy) = video.truth[0];
    let ref_hist = weighted_histogram(&video.frames[0], cx, cy, 6);
    let particles: Vec<(i32, i32)> = (0..PF_PARTICLES)
        .map(|_| (rng.range_i64(0, 64) as i32, rng.range_i64(0, 48) as i32))
        .collect();
    let cands: Vec<[i32; BINS]> = particles
        .iter()
        .map(|&(x, y)| {
            let h = weighted_histogram(&video.frames[1], x, y, 6);
            let mut o = [0i32; BINS];
            for (dst, &c) in o.iter_mut().zip(&h) {
                *dst = c as i32;
            }
            o
        })
        .collect();
    let mut rh = [0i32; BINS];
    for (dst, &c) in rh.iter_mut().zip(&ref_hist) {
        *dst = c as i32;
    }
    let (center, rho) = pf.weights(&rh, &cands, &particles).expect("run");
    println!(
        "  artifact center for {} random particles: {center:?} (max rho {})",
        PF_PARTICLES,
        rho.iter().max().unwrap()
    );
}

#[cfg(not(feature = "pjrt"))]
fn xla_cross_check(_video: &Video) {
    println!("(built without the `pjrt` feature — skipping the XLA cross-check)");
}
