//! Quickstart: the paper's Fig 1 flow end to end on a toy application.
//!
//! 1. Express an application as message-passing processing elements
//!    (phase 1): a splitter, two squarers, and an accumulator.
//! 2. Wrap them (Data Collector / Processor / Distributor) and plug them
//!    onto a CONNECT-style mesh NoC.
//! 3. Partition the same NoC across two FPGAs with quasi-SERDES links
//!    (phase 2) — same results, a few more cycles.
//!
//! Run: `cargo run --release --example quickstart`

use fabricflow::noc::{Network, NocConfig, Topology};
use fabricflow::partition::Partition;
use fabricflow::pe::collector::ArgMessage;
use fabricflow::pe::{OutMessage, PeSystem, Processor, WrapperSpec};
use fabricflow::serdes::SerdesConfig;

/// Splits an input value into two messages for the squarers.
struct Splitter {
    values: Vec<u64>,
    sq_a: usize,
    sq_b: usize,
}
impl Processor for Splitter {
    fn spec(&self) -> WrapperSpec {
        WrapperSpec::new(vec![32], vec![32, 32])
    }
    fn boot(&mut self) -> Vec<OutMessage> {
        self.values
            .iter()
            .enumerate()
            .flat_map(|(e, &v)| {
                vec![
                    OutMessage::word(self.sq_a, 0, e as u32, v, 32),
                    OutMessage::word(self.sq_b, 0, e as u32, v + 1, 32),
                ]
            })
            .collect()
    }
    fn process(&mut self, _: &[ArgMessage], _: u32) -> Vec<OutMessage> {
        Vec::new()
    }
}

/// Squares its argument (latency 4 — a 2-stage multiplier datapath).
struct Squarer {
    acc: usize,
    arg_at_acc: u8,
}
impl Processor for Squarer {
    fn spec(&self) -> WrapperSpec {
        WrapperSpec::new(vec![32], vec![64])
    }
    fn latency(&self) -> u64 {
        4
    }
    fn process(&mut self, args: &[ArgMessage], epoch: u32) -> Vec<OutMessage> {
        let x = args[0].payload[0];
        vec![OutMessage::word(self.acc, self.arg_at_acc, epoch, x * x, 64)]
    }
}

/// Adds the two squares and reports to the sink endpoint.
struct Accumulator {
    sink: usize,
}
impl Processor for Accumulator {
    fn spec(&self) -> WrapperSpec {
        WrapperSpec::new(vec![64, 64], vec![64])
    }
    fn process(&mut self, args: &[ArgMessage], epoch: u32) -> Vec<OutMessage> {
        let s = args[0].payload[0] + args[1].payload[0];
        vec![OutMessage::word(self.sink, 0, epoch, s, 64)]
    }
}

fn build() -> PeSystem {
    let net = Network::new(&Topology::Mesh { w: 3, h: 2 }, NocConfig::paper());
    let mut sys = PeSystem::new(net);
    sys.attach(0, Box::new(Splitter { values: (1..=10).collect(), sq_a: 1, sq_b: 2 }));
    sys.attach(1, Box::new(Squarer { acc: 3, arg_at_acc: 0 }));
    sys.attach(2, Box::new(Squarer { acc: 3, arg_at_acc: 1 }));
    sys.attach(3, Box::new(Accumulator { sink: 5 }));
    sys
}

fn drain(sys: &mut PeSystem) -> Vec<(u32, u64)> {
    let mut out = Vec::new();
    let mut groups: std::collections::HashMap<u32, Vec<fabricflow::noc::Flit>> =
        Default::default();
    while let Some(f) = sys.net.eject(5) {
        groups.entry(f.tag >> 8).or_default().push(f);
    }
    for (epoch, flits) in groups {
        let words = fabricflow::noc::flit::depacketize(&flits, 64, 16);
        out.push((epoch, words[0]));
    }
    out.sort_unstable();
    out
}

fn main() {
    // Phase 1: PEs on a single-FPGA NoC.
    let mut sys = build();
    let cycles = sys.run(1_000_000);
    let results = drain(&mut sys);
    println!("single FPGA: {cycles} cycles");
    for &(e, v) in &results {
        let x = e as u64 + 1;
        assert_eq!(v, x * x + (x + 1) * (x + 1));
        println!("  epoch {e}: {x}² + {}² = {v}", x + 1);
    }

    // Phase 2: same design across two FPGAs (left column vs the rest).
    let mut sys2 = build();
    let part = Partition::new(2, vec![0, 1, 1, 0, 1, 1]);
    let cuts = part.apply(&mut sys2.net, SerdesConfig::default());
    let cycles2 = sys2.run(1_000_000);
    let results2 = drain(&mut sys2);
    assert_eq!(results, results2, "partitioning must not change results");
    println!(
        "two FPGAs ({} links cut, 8-wire quasi-SERDES): {cycles2} cycles (+{})",
        cuts.len(),
        cycles2 - cycles
    );
    println!("quickstart OK");
}
