//! Quickstart: the paper's Fig 1 flow end to end on a toy application,
//! built entirely through the unified `flow` API.
//!
//! 1. Express an application as message-passing processing elements
//!    (phase 1): a splitter, two squarers, and an accumulator.
//! 2. Register them on a [`fabricflow::flow::FlowBuilder`] — the builder
//!    wraps each PE (Data Collector / Processor / Distributor) and plugs
//!    it onto a CONNECT-style mesh NoC.
//! 3. Partition the same NoC across two FPGAs with quasi-SERDES links
//!    (phase 2) — same results, a few more cycles, one `RunReport`.
//!
//! Run: `cargo run --release --example quickstart`

use fabricflow::flow::{FlowBuilder, MappedFlow};
use fabricflow::noc::Topology;
use fabricflow::partition::Partition;
use fabricflow::pe::collector::ArgMessage;
use fabricflow::pe::{MsgSink, Processor, WrapperSpec};
use fabricflow::serdes::SerdesConfig;

/// Splits an input value into two messages for the squarers.
struct Splitter {
    values: Vec<u64>,
    sq_a: usize,
    sq_b: usize,
}
impl Processor for Splitter {
    fn spec(&self) -> WrapperSpec {
        WrapperSpec::new(vec![32], vec![32, 32])
    }
    fn boot(&mut self, out: &mut MsgSink) {
        for (e, &v) in self.values.iter().enumerate() {
            out.word(self.sq_a, 0, e as u32, v, 32);
            out.word(self.sq_b, 0, e as u32, v + 1, 32);
        }
    }
    fn process(&mut self, _: &[ArgMessage], _: u32, _: &mut MsgSink) {}
}

/// Squares its argument (latency 4 — a 2-stage multiplier datapath).
struct Squarer {
    acc: usize,
    arg_at_acc: u8,
}
impl Processor for Squarer {
    fn spec(&self) -> WrapperSpec {
        WrapperSpec::new(vec![32], vec![64])
    }
    fn latency(&self) -> u64 {
        4
    }
    fn process(&mut self, args: &[ArgMessage], epoch: u32, out: &mut MsgSink) {
        let x = args[0].payload[0];
        out.word(self.acc, self.arg_at_acc, epoch, x * x, 64);
    }
}

/// Adds the two squares and reports to the sink endpoint.
struct Accumulator {
    sink: usize,
}
impl Processor for Accumulator {
    fn spec(&self) -> WrapperSpec {
        WrapperSpec::new(vec![64, 64], vec![64])
    }
    fn process(&mut self, args: &[ArgMessage], epoch: u32, out: &mut MsgSink) {
        let s = args[0].payload[0] + args[1].payload[0];
        out.word(self.sink, 0, epoch, s, 64);
    }
}

/// One builder for both phases: the partition is the only difference.
fn build(partitioned: bool) -> MappedFlow {
    let mut fb = FlowBuilder::new("quickstart");
    fb.topology(Topology::Mesh { w: 3, h: 2 })
        .pe_at("split", 0, Box::new(Splitter { values: (1..=10).collect(), sq_a: 1, sq_b: 2 }))
        .pe_at("square_a", 1, Box::new(Squarer { acc: 3, arg_at_acc: 0 }))
        .pe_at("square_b", 2, Box::new(Squarer { acc: 3, arg_at_acc: 1 }))
        .pe_at("acc", 3, Box::new(Accumulator { sink: 5 }))
        .tap_at("sums", 5)
        .channel("split", "square_a")
        .channel("split", "square_b")
        .channel("square_a", "acc")
        .channel("square_b", "acc")
        .channel("acc", "sums");
    if partitioned {
        // Left mesh column on FPGA 0, the rest on FPGA 1.
        fb.partition(Partition::new(2, vec![0, 1, 1, 0, 1, 1]))
            .serdes(SerdesConfig::default());
    }
    fb.build().expect("quickstart flow is well-formed")
}

fn drain(flow: &mut MappedFlow) -> Vec<(u32, u64)> {
    flow.drain_messages("sums", 64)
        .into_iter()
        .map(|m| (m.epoch, m.words[0]))
        .collect()
}

fn main() {
    // Phase 1: PEs on a single-FPGA NoC.
    let mut flow = build(false);
    let report = flow.run().expect("single-FPGA run");
    let results = drain(&mut flow);
    println!("single FPGA: {} cycles", report.cycles);
    for &(e, v) in &results {
        let x = e as u64 + 1;
        assert_eq!(v, x * x + (x + 1) * (x + 1));
        println!("  epoch {e}: {x}² + {}² = {v}", x + 1);
    }

    // Phase 2: same design across two FPGAs — only the builder's
    // partition line changes; PEs, channels and results do not.
    let mut flow2 = build(true);
    let report2 = flow2.run().expect("partitioned run");
    let results2 = drain(&mut flow2);
    assert_eq!(results, results2, "partitioning must not change results");
    println!(
        "two FPGAs ({} links cut, 8-wire quasi-SERDES): {} cycles (+{})",
        report2.cut_links,
        report2.cycles,
        report2.cycles - report.cycles
    );
    for (f, r) in report2.resources_per_fpga.iter().enumerate() {
        println!("  FPGA {f}: {r} | serdes pins {}", report2.pins_per_fpga[f]);
    }
    println!("  {report2}");
    println!("quickstart OK");
}
