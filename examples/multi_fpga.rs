//! Phase-2 driver (paper §III, Figs 5–6): partitioning a NoC across
//! FPGAs over quasi-SERDES links — the Fig 5 example, pin budgets,
//! per-FPGA resource fit, serialization sweeps, and the automatic
//! min-cut partitioner extension — with every system constructed through
//! the unified `flow` API.
//!
//! Every partitioned run here executes as a TRUE sharded co-simulation
//! (`FlowBuilder::multichip`): one `Network` per FPGA, each cut link a
//! pair of wire channels that serialize every flit MSB-first across the
//! chip boundary — not the analytic single-network serdes splice.
//!
//! Run: `cargo run --release --example multi_fpga`

use fabricflow::flow::{FlowBuilder, MappedFlow, RunReport};
use fabricflow::noc::Topology;
use fabricflow::partition::Partition;
use fabricflow::pe::collector::ArgMessage;
use fabricflow::pe::{MsgSink, Processor, WrapperSpec};
use fabricflow::resources::Device;
use fabricflow::serdes::SerdesConfig;

/// Boot-time scatter source: sends `count` single-flit messages
/// round-robin across `dsts` (the taps), then stays idle.
struct Scatter {
    dsts: Vec<usize>,
    count: u32,
}
impl Processor for Scatter {
    fn spec(&self) -> WrapperSpec {
        WrapperSpec::new(vec![16], vec![16])
    }
    fn boot(&mut self, out: &mut MsgSink) {
        for i in 0..self.count {
            let dst = self.dsts[i as usize % self.dsts.len()];
            // Epochs stay under 256: the quasi-serdes wire format carries
            // a 16-bit tag = (epoch << 8) | arg, and the sharded co-sim
            // genuinely serializes every cut-crossing flit. Taps drain
            // raw flits, so epoch reuse is harmless here.
            out.word(dst, 0, i & 0xFF, (i as u64) & 0xFFFF, 16);
        }
    }
    fn process(&mut self, _: &[ArgMessage], _: u32, _: &mut MsgSink) {}
}

/// The Fig 5 NoC: 4 routers in a cycle, one endpoint each.
fn fig5_topology() -> Topology {
    Topology::Custom {
        n_routers: 4,
        links: vec![(0, 1), (1, 2), (2, 3), (3, 0)],
        endpoint_router: vec![0, 1, 2, 3],
    }
}

/// Fig 5 flow: a scatter source at N0 flooding taps at N1–N3; optionally
/// R0 (+ its PE) on its own FPGA behind `serdes` links — simulated as a
/// sharded two-chip fabric via `FlowBuilder::multichip`.
fn fig5_flow(serdes: Option<SerdesConfig>) -> MappedFlow {
    let mut fb = FlowBuilder::new("fig5");
    fb.topology(fig5_topology())
        .pe_at("src", 0, Box::new(Scatter { dsts: vec![1, 2, 3], count: 3000 }))
        .tap_at("n1", 1)
        .tap_at("n2", 2)
        .tap_at("n3", 3)
        .channel("src", "n1")
        .channel("src", "n2")
        .channel("src", "n3");
    if let Some(s) = serdes {
        fb.partition(Partition::island(4, &[0])).multichip(s);
    }
    fb.build().expect("fig5 flow is well-formed")
}

fn run(mut flow: MappedFlow) -> RunReport {
    let report = flow.run().expect("flow drains");
    // Sanity: every scattered flit reached a tap.
    let got = flow.drain("n1").len() + flow.drain("n2").len() + flow.drain("n3").len();
    assert_eq!(got, 3000, "lost flits");
    report
}

fn main() {
    println!("== Fig 5: 4-router NoC, R0 (+N0) on its own FPGA ==");
    let part = Partition::island(4, &[0]);
    let g = fig5_topology().build();
    let serdes = SerdesConfig::default();
    println!("  cut links: {:?}", part.cut_links(&g));
    println!(
        "  pins per FPGA (8-wire links, both directions): {:?}",
        part.pins_per_fpga(&g, &serdes)
    );
    let base = run(fig5_flow(None));
    let cut = run(fig5_flow(Some(serdes)));
    for (f, r) in cut.resources_per_fpga.iter().enumerate() {
        println!(
            "  FPGA {f}: NoC infrastructure + wrapper {r} — fits DE0-Nano: {}",
            Device::DE0_NANO.fits(*r)
        );
    }
    println!(
        "  3000 flits: 1 FPGA {} cycles, 2 sharded FPGAs {} cycles ({} wire flits)",
        base.cycles, cut.cycles, cut.serdes_flits
    );
    for (chip, s) in cut.per_chip.iter().enumerate() {
        println!("    chip {chip}: {s}");
    }
    for l in &cut.links {
        println!(
            "    wire R{}→R{} (chip {}→{}): {} flits, {} cyc/flit, {:.1}% occupied, {} stalls",
            l.from.0,
            l.to.0,
            l.from_chip,
            l.to_chip,
            l.carried,
            l.cycles_per_flit,
            100.0 * l.occupancy(cut.net.cycles),
            l.stall_cycles
        );
    }

    println!("== serialization sweep (paper: 'depending on ... pins available') ==");
    // Batched form of the same sweep: one fresh flow per pin count.
    let pin_sweep = [1u32, 2, 4, 8, 16];
    let runs = MappedFlow::run_batch(
        pin_sweep,
        |&pins| Ok(fig5_flow(Some(SerdesConfig { pins, clock_div: 1, tx_buffer: 8 }))),
        |_, flow| flow.drain("n1").len() + flow.drain("n2").len() + flow.drain("n3").len(),
    )
    .expect("pin sweep");
    for (&pins, (got, report)) in pin_sweep.iter().zip(&runs) {
        assert_eq!(*got, 3000, "lost flits at {pins} pins");
        println!(
            "  {pins:2} pins ({:2} cycles/flit on the link): {} cycles",
            report.serdes_cycles_per_flit, report.cycles
        );
    }

    println!("== off-chip clock divider sweep ==");
    for div in [1u32, 2, 4] {
        let cfg = SerdesConfig { pins: 8, clock_div: div, tx_buffer: 8 };
        println!("  I/O clock 1/{div}: {} cycles", run(fig5_flow(Some(cfg))).cycles);
    }

    println!("== automatic min-cut bisection of an 8x8 torus (extension) ==");
    for n_fpgas in [2usize, 4] {
        // 8 scatter PEs feeding 56 taps, partitioned automatically by the
        // builder via Partition::balanced.
        let mut fb = FlowBuilder::new("torus-auto");
        fb.topology(Topology::Torus { w: 8, h: 8 })
            .auto_partition(n_fpgas)
            .multichip(SerdesConfig::default())
            .seed(42);
        let taps: Vec<usize> = (8..64).collect();
        for p in 0..8usize {
            fb.pe_at(
                &format!("src{p}"),
                p,
                Box::new(Scatter { dsts: taps.clone(), count: 1250 }),
            );
        }
        for &t in &taps {
            fb.tap_at(&format!("t{t}"), t);
        }
        let mut flow = fb.build().expect("torus flow");
        let auto = flow.partition().expect("auto partition resolved").clone();
        let report = flow.run().expect("torus flow drains");
        println!(
            "  {n_fpgas} FPGAs: sizes {:?}, {} links cut, pins/FPGA {:?}",
            auto.sizes(),
            report.cut_links,
            report.pins_per_fpga
        );
        println!(
            "    10k flits drained in {} cycles across {} sharded chips ({} wire flits)",
            report.cycles,
            report.per_chip.len(),
            report.serdes_flits
        );
    }
    println!("multi_fpga OK");
}
