//! Phase-2 driver (paper §III, Figs 5–6): partitioning a NoC across
//! FPGAs over quasi-SERDES links — the Fig 5 example, pin budgets,
//! per-FPGA resource fit, serialization sweeps, and the automatic
//! min-cut partitioner extension.
//!
//! Run: `cargo run --release --example multi_fpga`

use fabricflow::noc::{Flit, Network, NocConfig, Topology};
use fabricflow::partition::Partition;
use fabricflow::resources::Device;
use fabricflow::serdes::{wire_bits, SerdesConfig};
use fabricflow::util::Rng;

fn traffic(net: &mut Network, flits: u32, seed: u64) -> u64 {
    let n = net.n_endpoints();
    let mut rng = Rng::new(seed);
    for i in 0..flits {
        let s = rng.index(n);
        let d = (s + 1 + rng.index(n - 1)) % n;
        net.inject(s, Flit::single(s, d, i, i as u64));
    }
    net.run_until_idle(100_000_000)
}

fn main() {
    println!("== Fig 5: 4-router NoC, R0 (+N0) on its own FPGA ==");
    let topo = Topology::Custom {
        n_routers: 4,
        links: vec![(0, 1), (1, 2), (2, 3), (3, 0)],
        endpoint_router: vec![0, 1, 2, 3],
    };
    let part = Partition::island(4, &[0]);
    let g = topo.build();
    let serdes = SerdesConfig::default();
    println!("  cut links: {:?}", part.cut_links(&g));
    println!(
        "  pins per FPGA (8-wire links, both directions): {:?}",
        part.pins_per_fpga(&g, &serdes)
    );
    let res = part.noc_resources_per_fpga(&g, &NocConfig::paper(), &serdes);
    for (f, r) in res.iter().enumerate() {
        println!(
            "  FPGA {f}: NoC infrastructure {r} — fits DE0-Nano: {}",
            Device::DE0_NANO.fits(*r)
        );
    }
    let mut mono = Network::new(&topo, NocConfig::paper());
    let base = traffic(&mut mono, 3000, 1);
    let mut split = Network::new(&topo, NocConfig::paper());
    part.apply(&mut split, serdes);
    let cut = traffic(&mut split, 3000, 1);
    println!("  3000 flits: 1 FPGA {base} cycles, 2 FPGAs {cut} cycles");

    println!("== serialization sweep (paper: 'depending on ... pins available') ==");
    let bits = wire_bits(16, 4);
    for pins in [1u32, 2, 4, 8, 16] {
        let cfg = SerdesConfig { pins, clock_div: 1, tx_buffer: 8 };
        let mut net = Network::new(&topo, NocConfig::paper());
        part.apply(&mut net, cfg);
        let cycles = traffic(&mut net, 3000, 1);
        println!(
            "  {pins:2} pins ({:2} cycles/flit on the link): {cycles} cycles",
            cfg.cycles_per_flit(bits)
        );
    }

    println!("== off-chip clock divider sweep ==");
    for div in [1u32, 2, 4] {
        let cfg = SerdesConfig { pins: 8, clock_div: div, tx_buffer: 8 };
        let mut net = Network::new(&topo, NocConfig::paper());
        part.apply(&mut net, cfg);
        println!("  I/O clock 1/{div}: {} cycles", traffic(&mut net, 3000, 1));
    }

    println!("== automatic min-cut bisection of an 8x8 torus (extension) ==");
    let torus = Topology::Torus { w: 8, h: 8 };
    let tg = torus.build();
    for n_fpgas in [2usize, 4] {
        let auto = Partition::balanced(&tg, n_fpgas, 42);
        let cut = auto.cut_links(&tg).len();
        println!(
            "  {n_fpgas} FPGAs: sizes {:?}, {cut} links cut, pins/FPGA {:?}",
            auto.sizes(),
            auto.pins_per_fpga(&tg, &serdes)
        );
        let mut net = Network::new(&torus, NocConfig::paper());
        auto.apply(&mut net, serdes);
        let cycles = traffic(&mut net, 10_000, 7);
        println!("    10k flits drained in {cycles} cycles");
    }
    println!("multi_fpga OK");
}
